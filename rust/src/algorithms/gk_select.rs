//! **GK Select** (§V, appendix Fig. 5) — the paper's contribution.
//!
//! An exact k-th order statistic in exactly three rounds:
//!
//! 1. **Approximate pivot** — per-partition GK sketches, collected and
//!    merged on the driver; the queried quantile becomes the pivot `π`
//!    (rank error ≤ εn by the GK guarantee).
//! 2. **Count** — `π` is TorrentBroadcast; each executor counts `<π`,
//!    `=π`, `>π` in one linear pass (the AOT kernel / native backend);
//!    the driver reduces the counts and computes the signed rank error
//!    `Δk`. If the target rank falls inside the `=π` run, `π` *is* the
//!    exact answer.
//! 3. **Candidate extraction** — `Δk` is broadcast; each executor Dutch-
//!    partitions its partition around `π` and QuickSelects the `|Δk|`
//!    rank-closest values on the correct side; slices are treeReduce-
//!    merged, discarding everything farther than `|Δk|` ranks from `π`;
//!    the boundary value of the surviving slice is the exact quantile.
//!
//! No shuffle, no persist, `O(n/P)` executor work outside the sketch, and
//! candidate traffic bounded by `|Δk| ≤ εn` per message.

use super::approx_quantile::{build_global_sketch, MergeStrategy, SketchVariant};
use super::{make_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::runtime::{KernelBackend, NativeBackend};
use crate::{target_rank, Key};
use anyhow::{ensure, Result};

/// Tuning knobs for GK Select.
#[derive(Debug, Clone)]
pub struct GkSelectParams {
    /// Sketch relative error — controls pivot quality and candidate
    /// volume (`|Δk| ≤ εn`); the ablation bench sweeps this.
    pub epsilon: f64,
    /// Which GK variant runs on executors.
    pub variant: SketchVariant,
    /// Driver-side sketch merge (fold = Spark, tree = mSGK).
    pub merge: MergeStrategy,
    /// treeReduce depth override for Round 3 (None → ⌈log₂P⌉).
    pub tree_depth: Option<usize>,
    /// Pivot RNG seed (QuickSelect pivots inside `secondPass`).
    pub seed: u64,
}

impl Default for GkSelectParams {
    fn default() -> Self {
        Self {
            epsilon: 0.01,
            // §Perf L3.4: bulk (radix-sort + direct summary) is ~1.5× the
            // streamed mSGK on the round-1 hot path and keeps the same
            // ε-guarantee; switch back to Modified/Spark to model Spark's
            // streaming executors.
            variant: SketchVariant::Bulk,
            merge: MergeStrategy::Fold,
            tree_depth: None,
            seed: 0x6B53_E1EC,
        }
    }
}

/// The GK Select driver. Owns the kernel backend used for Round 2's
/// count pass.
pub struct GkSelect {
    pub params: GkSelectParams,
    backend: Box<dyn KernelBackend>,
}

impl GkSelect {
    /// Native-backend instance (no artifacts needed).
    pub fn new(params: GkSelectParams) -> Self {
        Self {
            params,
            backend: Box::new(NativeBackend::new()),
        }
    }

    /// Run Round 2's count pass through a specific backend (e.g. the
    /// PJRT-compiled Pallas kernel).
    pub fn with_backend(params: GkSelectParams, backend: Box<dyn KernelBackend>) -> Self {
        Self { params, backend }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

/// `secondPass`: extract the `|Δk|` rank-closest values on the side `Δk`
/// points at.
///
/// The paper's appendix materializes the whole partition (`it.toArray`)
/// and Dutch-partitions it. Only one side of the pivot can ever contain
/// candidates, so we filter that side directly (one branch-predictable
/// pass, ~half the copies, no swap traffic) and select with Floyd–Rivest
/// — semantics identical, executor memory drops from `O(n_i)` to
/// `O(side)` (§Perf iteration L3.1).
pub(crate) fn second_pass(part: &[Key], pivot: Key, delta: i64, _seed: u64) -> Vec<Key> {
    debug_assert!(delta != 0);
    if delta < 0 {
        // target left of π: the |Δk| largest values below π
        let mut side: Vec<Key> = part.iter().copied().filter(|&v| v < pivot).collect();
        let l = side.len();
        let m = (-delta) as usize;
        let tgt = l.saturating_sub(m);
        if tgt > 0 && tgt < l {
            // §Perf L3.2: std's introselect measured ~2× our Floyd–Rivest
            side.select_nth_unstable(tgt);
        }
        side[tgt..].to_vec()
    } else {
        // target right of π: the Δk smallest values above π
        let mut side: Vec<Key> = part.iter().copied().filter(|&v| v > pivot).collect();
        let take = (delta as usize).min(side.len());
        if take > 0 && take < side.len() {
            side.select_nth_unstable(take - 1);
        }
        side.truncate(take);
        side
    }
}

/// `reduceSlices` (appendix): merge two candidate slices, keeping only
/// the `|Δk|` values that can still be the answer.
pub(crate) fn reduce_slices(a: Vec<Key>, b: Vec<Key>, delta: i64, _seed: u64) -> Vec<Key> {
    let mut c = a;
    c.extend_from_slice(&b);
    let m = delta.unsigned_abs() as usize;
    if c.len() <= m {
        return c;
    }
    if delta < 0 {
        // keep the m largest
        let tgt = c.len() - m;
        c.select_nth_unstable(tgt);
        c.drain(..tgt);
        c
    } else {
        // keep the m smallest
        c.select_nth_unstable(m - 1);
        c.truncate(m);
        c
    }
}

impl QuantileAlgorithm for GkSelect {
    fn name(&self) -> &'static str {
        "GK Select"
    }

    fn exact(&self) -> bool {
        true
    }

    fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        ensure!(!data.is_empty(), "empty dataset");
        cluster.reset_run();
        let n = data.len();
        let k = target_rank(n, q);

        // ---- Round 1: sketch-derived approximate pivot -----------------
        let sketch = build_global_sketch(
            cluster,
            data,
            self.params.variant,
            self.params.merge,
            self.params.epsilon,
        )?;
        let pivot = cluster
            .driver(|| sketch.query_quantile(q))
            .ok_or_else(|| anyhow::anyhow!("empty sketch"))?;

        // ---- Round 2: count around the pivot ---------------------------
        cluster.broadcast(&pivot);
        let backend = self.backend.as_mut();
        let pending = cluster.map_partitions(data, |part, _| {
            let c = backend.count_pivot(part, pivot);
            (c.lt, c.eq, c.gt)
        });
        let (lt, eq, _gt) = cluster
            .reduce(pending, |a, b| (a.0 + b.0, a.1 + b.1, a.2 + b.2))
            .expect("nonempty dataset");

        if lt <= k && k < lt + eq {
            // pivot is the exact answer — 2 rounds
            return Ok(make_report(self.name(), true, cluster, n, pivot));
        }

        // signed rank distance from the pivot's run to the target
        // (i64: a pivot below the whole dataset would make lt+eq-1
        // underflow in u64 — the sketch always returns a data value so
        // eq ≥ 1 in practice, but stay defensive)
        let approx_rank = if lt + eq <= k {
            lt as i64 + eq as i64 - 1
        } else {
            lt as i64
        };
        let delta = k as i64 - approx_rank;
        debug_assert!(delta != 0);

        // ---- Round 3: candidate extraction + treeReduce ----------------
        cluster.broadcast(&delta);
        let seed = self.params.seed;
        let slices = cluster.map_partitions(data, |part, ctx| {
            second_pass(part, pivot, delta, seed ^ (ctx.partition as u64) << 7)
        });
        let mut merge_salt = seed;
        let final_slice = cluster
            .tree_reduce(slices, self.params.tree_depth, |a, b| {
                merge_salt = merge_salt.wrapping_add(0x9E37);
                reduce_slices(a, b, delta, merge_salt)
            })
            .expect("nonempty dataset");

        let value = cluster.driver(|| {
            if delta < 0 {
                final_slice.iter().copied().min()
            } else {
                final_slice.iter().copied().max()
            }
        });
        let value = value.ok_or_else(|| {
            anyhow::anyhow!("empty candidate slice: Δk={delta}, lt={lt}, eq={eq}, k={k}")
        })?;
        Ok(make_report(self.name(), true, cluster, n, value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn check(dist: Distribution, n: u64, q: f64, eps: f64) -> Outcome {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(33).generate(&mut c, n);
        let truth = oracle_quantile(&data, q).unwrap();
        let mut alg = GkSelect::new(GkSelectParams {
            epsilon: eps,
            ..Default::default()
        });
        let out = alg.quantile(&mut c, &data, q).unwrap();
        assert_eq!(
            out.value, truth,
            "{}: exactness violated at q={q} n={n} eps={eps}",
            dist.label()
        );
        out
    }

    #[test]
    fn exact_median_uniform() {
        let out = check(Distribution::Uniform, 100_000, 0.5, 0.01);
        assert!(out.report.rounds <= 3, "rounds = {}", out.report.rounds);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
    }

    #[test]
    fn exact_p99_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            check(dist, 50_000, 0.99, 0.01);
            check(dist, 50_000, 0.5, 0.01);
        }
    }

    #[test]
    fn exact_extreme_quantiles() {
        check(Distribution::Uniform, 20_000, 0.0, 0.02);
        check(Distribution::Uniform, 20_000, 1.0, 0.02);
        check(Distribution::Uniform, 20_000, 0.001, 0.02);
        check(Distribution::Uniform, 20_000, 0.999, 0.02);
    }

    #[test]
    fn exact_with_coarse_epsilon() {
        // big eps → far pivot → large |Δk| → stresses secondPass/reduce
        check(Distribution::Uniform, 50_000, 0.5, 0.2);
        check(Distribution::Zipf, 50_000, 0.5, 0.2);
    }

    #[test]
    fn duplicate_heavy_hits_eq_run() {
        // zipf s=2.5: one value dominates; median almost surely in an eq run
        let out = check(Distribution::Zipf, 30_000, 0.5, 0.01);
        // eq-run exit is 2 rounds
        assert!(out.report.rounds <= 3);
    }

    #[test]
    fn three_rounds_no_shuffle_no_persist() {
        let out = check(Distribution::Uniform, 60_000, 0.75, 0.01);
        assert_eq!(out.report.rounds, 3);
        assert_eq!(out.report.stage_boundaries, 3);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
        assert!(out.report.exact);
    }

    #[test]
    fn candidate_volume_bounded_by_epsilon() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let n = 100_000u64;
        let data = Distribution::Uniform.generator(5).generate(&mut c, n);
        let mut alg = GkSelect::new(GkSelectParams {
            epsilon: 0.01,
            ..Default::default()
        });
        let out = alg.quantile(&mut c, &data, 0.25).unwrap();
        // slices ≤ P·|Δk| keys ≤ P·εn; generous bound with overheads
        let bound = 8 * (0.01 * n as f64) as u64 * 4 * 4;
        assert!(
            out.report.network_volume_bytes < bound + 100_000,
            "candidate traffic {} vs bound {bound}",
            out.report.network_volume_bytes
        );
    }

    #[test]
    fn tiny_inputs() {
        for n in [1u64, 2, 3, 7, 8, 9] {
            let mut c = Cluster::new(ClusterConfig::local(2, 4));
            let data = Distribution::Uniform.generator(n).generate(&mut c, n.max(1));
            let truth = oracle_quantile(&data, 0.5).unwrap();
            let mut alg = GkSelect::new(GkSelectParams::default());
            let out = alg.quantile(&mut c, &data, 0.5).unwrap();
            assert_eq!(out.value, truth, "n={n}");
        }
    }

    #[test]
    fn second_pass_left_and_right() {
        // part = 0..10, pivot 5
        let part: Vec<Key> = (0..10).collect();
        // delta = -2: two largest below 5 → {3, 4}
        let mut s = second_pass(&part, 5, -2, 1);
        s.sort_unstable();
        assert_eq!(s, vec![3, 4]);
        // delta = 3: three smallest above 5 → {6, 7, 8}
        let mut s = second_pass(&part, 5, 3, 1);
        s.sort_unstable();
        assert_eq!(s, vec![6, 7, 8]);
    }

    #[test]
    fn second_pass_clamps_to_available() {
        let part: Vec<Key> = vec![1, 2, 9];
        // delta = 5 but only one element above pivot 8
        let s = second_pass(&part, 8, 5, 1);
        assert_eq!(s, vec![9]);
        // delta = -5 but only two below pivot 8
        let mut s = second_pass(&part, 8, -5, 1);
        s.sort_unstable();
        assert_eq!(s, vec![1, 2]);
    }

    #[test]
    fn reduce_slices_keeps_rank_closest() {
        // delta > 0: keep smallest
        let r = reduce_slices(vec![10, 4], vec![7, 2, 8], 2, 3);
        let mut r2 = r.clone();
        r2.sort_unstable();
        assert_eq!(r2, vec![2, 4]);
        // delta < 0: keep largest
        let r = reduce_slices(vec![10, 4], vec![7, 2, 8], -2, 3);
        let mut r2 = r.clone();
        r2.sort_unstable();
        assert_eq!(r2, vec![8, 10]);
        // under-full: keep all
        assert_eq!(reduce_slices(vec![1], vec![2], 5, 3).len(), 2);
    }
}
