//! The distributed quantile algorithms the paper evaluates (§IV–V), all
//! running on the [`crate::cluster`] substrate so rounds, stage
//! boundaries, and bytes are measured, not asserted.
//!
//! | Module | Paper §| Exact? | Rounds |
//! |---|---|---|---|
//! | [`gk_select`] | V (the contribution) | yes | 2 (3 on band overflow) |
//! | [`full_sort`] | IV-A (Spark default) | yes | 1 (+1 full shuffle) |
//! | [`afs`] | IV-B (Al-Furaih) | yes | `O(log n)` |
//! | [`jeffers`] | IV-C | yes | `O(log n)` |
//! | [`approx_quantile`] | IV-D (GK Sketch) | no | 1 |
//! | [`histogram_select`] | extension (§V-6 discussion) | yes | ≤ 2 + ⌈32/log₂bins⌉ |

pub mod afs;
pub mod approx_quantile;
pub mod count_discard;
pub mod full_sort;
pub mod gk_select;
pub mod histogram_select;
pub mod jeffers;
pub mod multi_select;

use crate::cluster::dataset::Dataset;
use crate::cluster::metrics::MetricsReport;
use crate::cluster::Cluster;
use crate::runtime::KernelBackend;
use crate::Key;
use anyhow::Result;

/// Result of one quantile query: the value plus the full measured report.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub value: Key,
    pub report: MetricsReport,
}

/// Common driver interface over all algorithms.
pub trait QuantileAlgorithm {
    fn name(&self) -> &'static str;

    /// Whether the returned value is the exact order statistic.
    fn exact(&self) -> bool;

    /// Answer quantile `q` over `data`. Resets the cluster's run ledger on
    /// entry so the report covers exactly this query.
    fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome>;
}

/// Build the end-of-run report for an algorithm.
pub(crate) fn make_report(
    name: &str,
    exact: bool,
    cluster: &Cluster,
    n: u64,
    value: Key,
) -> Outcome {
    Outcome {
        value,
        report: MetricsReport::from_metrics(
            name,
            n,
            cluster.cfg.partitions,
            cluster.cfg.executors,
            cluster.elapsed_secs(),
            &cluster.metrics,
            exact,
        ),
    }
}

/// [`make_report`] for algorithms that own a kernel backend: also
/// stamps the backend's active SIMD lane width, so every perf record
/// says which band-scan dispatch produced it. New backend-owning exit
/// paths must use this (not `make_report`) or their reports mislabel
/// the dispatch as scalar.
pub(crate) fn make_backend_report(
    name: &str,
    exact: bool,
    cluster: &Cluster,
    n: u64,
    value: Key,
    backend: &dyn KernelBackend,
) -> Outcome {
    let mut out = make_report(name, exact, cluster, n, value);
    out.report = out.report.with_simd_lane_width(backend.simd_lane_width());
    out
}

/// Ground-truth oracle: exact quantile by full local sort (tests and
/// verification runs only — this is what the algorithms are checked
/// against, never part of any measured path).
pub fn oracle_quantile(data: &Dataset<Key>, q: f64) -> Option<Key> {
    let mut all = data.to_vec();
    if all.is_empty() {
        return None;
    }
    all.sort_unstable();
    Some(all[crate::target_rank(all.len() as u64, q) as usize])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn oracle_median() {
        let d = Dataset::from_vec(vec![5, 1, 4, 2, 3], 2).unwrap();
        assert_eq!(oracle_quantile(&d, 0.5), Some(3));
        assert_eq!(oracle_quantile(&d, 0.0), Some(1));
        assert_eq!(oracle_quantile(&d, 1.0), Some(5));
    }

    #[test]
    fn oracle_empty() {
        let d: Dataset<Key> = Dataset::from_partitions(vec![vec![]]).unwrap();
        assert_eq!(oracle_quantile(&d, 0.5), None);
    }

    #[test]
    fn report_carries_cluster_shape() {
        let c = Cluster::new(ClusterConfig::local(2, 4));
        let o = make_report("x", true, &c, 100, 7);
        assert_eq!(o.report.partitions, 4);
        assert_eq!(o.report.executors, 2);
        assert_eq!(o.value, 7);
    }
}
