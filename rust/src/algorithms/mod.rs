//! The distributed quantile algorithms the paper evaluates (§IV–V), all
//! running on the [`crate::cluster`] substrate so rounds, stage
//! boundaries, and bytes are measured, not asserted.
//!
//! | Module | Paper §| Exact? | Rounds |
//! |---|---|---|---|
//! | [`gk_select`] | V (the contribution) | yes | 2 (3 on band overflow) |
//! | [`full_sort`] | IV-A (Spark default) | yes | 1 (+1 full shuffle) |
//! | [`afs`] | IV-B (Al-Furaih) | yes | `O(log n)` |
//! | [`jeffers`] | IV-C | yes | `O(log n)` |
//! | [`approx_quantile`] | IV-D (GK Sketch) | no | 1 |
//! | [`histogram_select`] | extension (§V-6 discussion) | yes | ≤ 2 + ⌈32/log₂bins⌉ |
//!
//! Since the [`crate::engine`] redesign, algorithms are **stateless
//! strategies**: the [`QuantileAlgorithm`] trait takes a typed
//! [`QuantileQuery`] plan and an [`EngineCtx`] carrying the engine's
//! cluster, kernel backend, and source dataset. The old one-method-per-
//! algorithm constructors remain as thin `#[deprecated]` shims for one
//! release — route new code through [`crate::engine::QuantileEngine`].

pub mod afs;
pub mod approx_quantile;
pub mod count_discard;
pub mod full_sort;
pub mod gk_select;
pub mod histogram_select;
pub mod jeffers;
pub mod multi_select;

use crate::cluster::dataset::Dataset;
use crate::cluster::metrics::MetricsReport;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::Key;

/// Result of one single-value query: the value plus the full measured
/// report. The engine-level equivalent (values plural, lane width
/// stamped) is [`QueryOutcome`]; `Outcome` remains the currency of the
/// per-algorithm internals and the deprecated shims.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub value: Key,
    pub report: MetricsReport,
}

/// Common strategy interface over all algorithms: execute one typed
/// query plan against the context's dataset. Strategies are stateless —
/// the kernel backend and the cluster arrive through the [`EngineCtx`],
/// so one engine-owned backend serves every algorithm (and the report's
/// SIMD lane width can be stamped in exactly one place, by the engine).
pub trait QuantileAlgorithm {
    fn name(&self) -> &'static str;

    /// Whether returned values are exact order statistics.
    fn exact(&self) -> bool;

    /// Execute `query` over `ctx.data`. Single-shot plans reset the
    /// cluster's run ledger on entry so the report covers exactly this
    /// query.
    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError>;
}

/// Shared plan dispatch: validates the query, then answers it through
/// the strategy's single-quantile closure. `Multi` loops the closure and
/// folds the per-run reports ([`MetricsReport::absorb`]) — strategies
/// with a native batched path (GK Select's fused multi-band scan)
/// intercept `Multi` before delegating here. `Sketched` always runs the
/// Spark-default GK sketch at the requested ε, strategy-independent.
pub(crate) fn drive_plan<S>(
    cluster: &mut Cluster,
    data: &Dataset<Key>,
    query: &QuantileQuery,
    mut single: S,
) -> Result<QueryOutcome, EngineError>
where
    S: FnMut(&mut Cluster, f64) -> Result<Outcome, EngineError>,
{
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    let n = data.len();
    query.validate(n)?;
    match query {
        QuantileQuery::Single(q) => Ok(single(cluster, *q)?.into()),
        QuantileQuery::Rank(k) => {
            Ok(single(cluster, crate::engine::rank_to_quantile(*k, n))?.into())
        }
        QuantileQuery::Multi(qs) => {
            let mut values = Vec::with_capacity(qs.len());
            let mut report: Option<MetricsReport> = None;
            for &q in qs {
                let out = single(cluster, q)?;
                values.push(out.value);
                report = Some(match report {
                    None => out.report,
                    Some(mut acc) => {
                        acc.absorb(&out.report);
                        acc
                    }
                });
            }
            Ok(QueryOutcome {
                values,
                report: report.expect("validated non-empty"),
                degraded: false,
            })
        }
        QuantileQuery::Sketched { q, eps } => {
            let params = approx_quantile::ApproxQuantileParams {
                epsilon: *eps,
                variant: approx_quantile::SketchVariant::Spark,
                merge: approx_quantile::MergeStrategy::Fold,
            };
            Ok(approx_quantile::sketch_quantile_with(cluster, data, &params, *q)?.into())
        }
    }
}

/// Build the end-of-run report for an algorithm from the cluster's live
/// ledger. The single report constructor — the engine stamps the SIMD
/// lane width afterwards, centrally, so there is no backend-aware
/// variant to forget (the old `make_backend_report` footgun).
pub(crate) fn run_report(name: &str, exact: bool, cluster: &Cluster, n: u64) -> MetricsReport {
    MetricsReport::from_metrics(
        name,
        n,
        cluster.cfg.partitions,
        cluster.cfg.executors,
        cluster.elapsed_secs(),
        &cluster.metrics,
        exact,
    )
}

/// Ground-truth oracle: exact quantile by full local sort (tests and
/// verification runs only — this is what the algorithms are checked
/// against, never part of any measured path).
pub fn oracle_quantile(data: &Dataset<Key>, q: f64) -> Option<Key> {
    let mut all = data.to_vec();
    if all.is_empty() {
        return None;
    }
    all.sort_unstable();
    Some(all[crate::target_rank(all.len() as u64, q) as usize])
}

#[cfg(test)]
pub(crate) fn plan_single(
    alg: &dyn QuantileAlgorithm,
    cluster: &mut Cluster,
    data: &Dataset<Key>,
    q: f64,
) -> Result<QueryOutcome, EngineError> {
    let backend = crate::runtime::NativeBackend::new();
    let mut ctx = EngineCtx {
        cluster,
        backend: &backend,
        data,
    };
    alg.execute_plan(&mut ctx, &QuantileQuery::Single(q))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn oracle_median() {
        let d = Dataset::from_vec(vec![5, 1, 4, 2, 3], 2).unwrap();
        assert_eq!(oracle_quantile(&d, 0.5), Some(3));
        assert_eq!(oracle_quantile(&d, 0.0), Some(1));
        assert_eq!(oracle_quantile(&d, 1.0), Some(5));
    }

    #[test]
    fn oracle_empty() {
        let d: Dataset<Key> = Dataset::from_partitions(vec![vec![]]).unwrap();
        assert_eq!(oracle_quantile(&d, 0.5), None);
    }

    #[test]
    fn report_carries_cluster_shape() {
        let c = Cluster::new(ClusterConfig::local(2, 4));
        let r = run_report("x", true, &c, 100);
        assert_eq!(r.partitions, 4);
        assert_eq!(r.executors, 2);
        assert_eq!(r.n, 100);
        assert_eq!(r.simd_lane_width, 1, "strategies never stamp lane width");
    }

    #[test]
    fn drive_plan_rejects_malformed_plans() {
        let mut c = Cluster::new(ClusterConfig::local(1, 2));
        let data = Dataset::from_vec(vec![1, 2, 3], 2).unwrap();
        let single = |_: &mut Cluster, _: f64| -> Result<Outcome, EngineError> {
            unreachable!("validation must fire first")
        };
        assert_eq!(
            drive_plan(&mut c, &data, &QuantileQuery::Single(-0.1), single).unwrap_err(),
            EngineError::BadQuantile(-0.1)
        );
        let single = |_: &mut Cluster, _: f64| -> Result<Outcome, EngineError> {
            unreachable!()
        };
        assert_eq!(
            drive_plan(&mut c, &data, &QuantileQuery::Rank(3), single).unwrap_err(),
            EngineError::BadRank { k: 3, n: 3 }
        );
        let empty: Dataset<Key> = Dataset::from_partitions(vec![vec![]]).unwrap();
        let single = |_: &mut Cluster, _: f64| -> Result<Outcome, EngineError> {
            unreachable!()
        };
        assert_eq!(
            drive_plan(&mut c, &empty, &QuantileQuery::Single(0.5), single).unwrap_err(),
            EngineError::EmptyInput
        );
    }
}
