//! Spark Full Sort quantile (§IV-A): `orderBy` the whole dataset via the
//! PSRS pipeline, then index the k-th record — the Spark-default exact
//! path GK Select is benchmarked against.

use super::{make_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::sort::psrs::{psrs_sort, PsrsParams};
use crate::{target_rank, Key};
use anyhow::{ensure, Result};

/// Full-sort exact quantile.
#[derive(Debug, Clone, Default)]
pub struct FullSortQuantile {
    pub params: PsrsParams,
}

impl FullSortQuantile {
    pub fn new(params: PsrsParams) -> Self {
        Self { params }
    }
}

impl QuantileAlgorithm for FullSortQuantile {
    fn name(&self) -> &'static str {
        "Full Sort"
    }

    fn exact(&self) -> bool {
        true
    }

    fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        ensure!(!data.is_empty(), "empty dataset");
        cluster.reset_run();
        let n = data.len();
        let sorted = psrs_sort(cluster, data, &self.params);
        let k = target_rank(n, q);
        let value = cluster.driver(|| sorted.kth(k));
        let value = value.ok_or_else(|| anyhow::anyhow!("rank {k} out of range"))?;
        Ok(make_report(self.name(), true, cluster, n, value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    #[test]
    fn exact_on_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            let mut c = Cluster::new(ClusterConfig::local(2, 8));
            let data = dist.generator(6).generate(&mut c, 30_000);
            for q in [0.0, 0.5, 0.99, 1.0] {
                let truth = oracle_quantile(&data, q).unwrap();
                let mut alg = FullSortQuantile::default();
                let out = alg.quantile(&mut c, &data, q).unwrap();
                assert_eq!(out.value, truth, "{} q={q}", dist.label());
            }
        }
    }

    #[test]
    fn moves_order_n_bytes() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Uniform.generator(8).generate(&mut c, 50_000);
        let mut alg = FullSortQuantile::default();
        let out = alg.quantile(&mut c, &data, 0.5).unwrap();
        assert_eq!(out.report.shuffles, 1);
        assert!(
            out.report.bytes_shuffled > 50_000 * 2,
            "full sort should move most of the data; moved {}",
            out.report.bytes_shuffled
        );
    }
}
