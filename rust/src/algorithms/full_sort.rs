//! Spark Full Sort quantile (§IV-A): `orderBy` the whole dataset via the
//! PSRS pipeline, then index the k-th record — the Spark-default exact
//! path GK Select is benchmarked against.

use super::{drive_plan, run_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::sort::psrs::{psrs_sort, PsrsParams};
use crate::{target_rank, Key};
use anyhow::Result;

/// PSRS sort + index through explicit params. Resets the run ledger.
pub(crate) fn full_sort_quantile_with(
    cluster: &mut Cluster,
    params: &PsrsParams,
    data: &Dataset<Key>,
    q: f64,
) -> Result<Outcome, EngineError> {
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    cluster.reset_run();
    let n = data.len();
    let sorted = psrs_sort(cluster, data, params)?;
    let k = target_rank(n, q);
    let value = cluster.driver(|| sorted.kth(k));
    let value =
        value.ok_or_else(|| EngineError::Execution(format!("rank {k} out of range")))?;
    Ok(Outcome {
        value,
        report: run_report("Full Sort", true, cluster, n),
    })
}

/// Full-sort exact quantile — the stateless strategy behind
/// `AlgoChoice::FullSort`.
#[derive(Debug, Clone, Default)]
pub struct FullSortQuantile {
    pub params: PsrsParams,
}

impl FullSortQuantile {
    pub fn new(params: PsrsParams) -> Self {
        Self { params }
    }

    /// One exact quantile — the pre-redesign entry point.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute` with `AlgoChoice::FullSort`"
    )]
    pub fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        Ok(full_sort_quantile_with(cluster, &self.params, data, q)?)
    }
}

impl QuantileAlgorithm for FullSortQuantile {
    fn name(&self) -> &'static str {
        "Full Sort"
    }

    fn exact(&self) -> bool {
        true
    }

    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let data = ctx.data;
        drive_plan(ctx.cluster, data, query, |cluster, q| {
            full_sort_quantile_with(cluster, &self.params, data, q)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    #[test]
    fn exact_on_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            let mut c = Cluster::new(ClusterConfig::local(2, 8));
            let data = dist.generator(6).generate(&mut c, 30_000);
            for q in [0.0, 0.5, 0.99, 1.0] {
                let truth = oracle_quantile(&data, q).unwrap();
                let out =
                    full_sort_quantile_with(&mut c, &PsrsParams::default(), &data, q).unwrap();
                assert_eq!(out.value, truth, "{} q={q}", dist.label());
            }
        }
    }

    #[test]
    fn moves_order_n_bytes() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Uniform.generator(8).generate(&mut c, 50_000);
        let out = full_sort_quantile_with(&mut c, &PsrsParams::default(), &data, 0.5).unwrap();
        assert_eq!(out.report.shuffles, 1);
        assert!(
            out.report.bytes_shuffled > 50_000 * 2,
            "full sort should move most of the data; moved {}",
            out.report.bytes_shuffled
        );
    }
}
