//! Jeffers Select for Spark (§IV-C): identical to AFS except the per-round
//! aggregation is a direct `collect` — cheaper setup than a treeReduce,
//! all-to-one traffic that only matters at very large `P`.

use super::count_discard::{AggMode, CountDiscardParams, CountDiscardSelect};
use super::{Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::Key;
use anyhow::Result;

/// Jeffers parameters (count-discard knobs).
pub type JeffersParams = CountDiscardParams;

/// Jeffers Select: `O(log n)` rounds, each ending in a collect.
pub struct Jeffers {
    inner: CountDiscardSelect,
}

impl Jeffers {
    pub fn new(params: JeffersParams) -> Self {
        Self {
            inner: CountDiscardSelect::new("Jeffers", AggMode::Collect, params),
        }
    }
}

impl QuantileAlgorithm for Jeffers {
    fn name(&self) -> &'static str {
        "Jeffers"
    }

    fn exact(&self) -> bool {
        true
    }

    fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        self.inner.quantile(cluster, data, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    #[test]
    fn jeffers_is_exact() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Zipf.generator(4).generate(&mut c, 20_000);
        let truth = oracle_quantile(&data, 0.75).unwrap();
        let mut alg = Jeffers::new(JeffersParams::default());
        let out = alg.quantile(&mut c, &data, 0.75).unwrap();
        assert_eq!(out.value, truth);
        assert_eq!(out.report.algorithm, "Jeffers");
    }

    #[test]
    fn jeffers_sends_more_driver_bytes_than_afs_at_scale() {
        // collect funnels every partition's stats to the driver each round
        let mut c = Cluster::new(ClusterConfig::local(4, 32));
        let data = Distribution::Uniform.generator(5).generate(&mut c, 100_000);
        let mut j = Jeffers::new(JeffersParams::default());
        let out_j = j.quantile(&mut c, &data, 0.5).unwrap();
        let mut a = super::super::afs::Afs::new(CountDiscardParams::default());
        let out_a = a.quantile(&mut c, &data, 0.5).unwrap();
        assert!(
            out_j.report.bytes_to_driver > out_a.report.bytes_to_driver,
            "jeffers {} !> afs {}",
            out_j.report.bytes_to_driver,
            out_a.report.bytes_to_driver
        );
    }
}
