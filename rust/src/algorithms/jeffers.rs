//! Jeffers Select for Spark (§IV-C): identical to AFS except the per-round
//! aggregation is a direct `collect` — cheaper setup than a treeReduce,
//! all-to-one traffic that only matters at very large `P`.

use super::count_discard::{AggMode, CountDiscardParams, CountDiscardSelect};
use super::{Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::Key;
use anyhow::Result;

/// Jeffers parameters (count-discard knobs).
pub type JeffersParams = CountDiscardParams;

/// Jeffers Select: `O(log n)` rounds, each ending in a collect — the
/// stateless strategy behind `AlgoChoice::Jeffers`.
pub struct Jeffers {
    inner: CountDiscardSelect,
}

impl Jeffers {
    pub fn new(params: JeffersParams) -> Self {
        Self {
            inner: CountDiscardSelect::new("Jeffers", AggMode::Collect, params),
        }
    }

    /// One exact quantile — the pre-redesign entry point.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute` with `AlgoChoice::Jeffers`"
    )]
    pub fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        Ok(self.inner.quantile_with(cluster, data, q)?)
    }
}

impl QuantileAlgorithm for Jeffers {
    fn name(&self) -> &'static str {
        "Jeffers"
    }

    fn exact(&self) -> bool {
        true
    }

    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        self.inner.execute_plan(ctx, query)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{oracle_quantile, plan_single};
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    #[test]
    fn jeffers_is_exact() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Zipf.generator(4).generate(&mut c, 20_000);
        let truth = oracle_quantile(&data, 0.75).unwrap();
        let alg = Jeffers::new(JeffersParams::default());
        let out = plan_single(&alg, &mut c, &data, 0.75).unwrap();
        assert_eq!(out.value(), truth);
        assert_eq!(out.report.algorithm, "Jeffers");
    }

    #[test]
    fn jeffers_sends_more_driver_bytes_than_afs_at_scale() {
        // collect funnels every partition's stats to the driver each round
        let mut c = Cluster::new(ClusterConfig::local(4, 32));
        let data = Distribution::Uniform.generator(5).generate(&mut c, 100_000);
        let j = Jeffers::new(JeffersParams::default());
        let out_j = plan_single(&j, &mut c, &data, 0.5).unwrap();
        let a = super::super::afs::Afs::new(CountDiscardParams::default());
        let out_a = plan_single(&a, &mut c, &data, 0.5).unwrap();
        assert!(
            out_j.report.bytes_to_driver > out_a.report.bytes_to_driver,
            "jeffers {} !> afs {}",
            out_j.report.bytes_to_driver,
            out_a.report.bytes_to_driver
        );
    }
}
