//! GK Multi-Select: answer **m quantiles exactly in the same 2 rounds**.
//!
//! The paper's §V runs once per quantile query; its round structure,
//! however, batches for free — an extension the evaluation (Figs. 3–4's
//! `…50`/`…99` pairs) invites. With the fused two-round protocol the
//! batched shape is:
//!
//! 1. build/merge the sketch **once**; query all m pivots *and* all m
//!    candidate bands `[loᵢ, hiᵢ]` from it;
//! 2. one fused pass classifies every partition against all m
//!    `(π, lo, hi)` triples **in a single read of the data**
//!    ([`crate::runtime::KernelBackend::multi_band_extract`]) and
//!    extracts every open-band candidate; one treeReduce merges the m
//!    `(counts, candidates)` slices side-by-side; the driver resolves
//!    each rank inside its extracted band.
//!
//! Queries whose band overflowed the candidate budget (or whose measured
//! counts contradict the sketch) fall back to one shared classic
//! extraction round — still ≤ 3 rounds for the whole batch. Marginal
//! cost per extra quantile is one more accumulator in the same scan; the
//! sketch (the dominant term) is shared.
//!
//! This is the machinery behind `QuantileQuery::Multi` on the GK Select
//! strategy — the engine's `execute` is the public entry point; the
//! backend-owning [`MultiSelect`] struct remains as a deprecated shim.

use super::approx_quantile::build_global_sketch;
use super::gk_select::{
    default_candidate_budget, pivot_delta, reduce_slices, resolve_band, second_pass,
    GkSelectParams,
};
use super::run_report;
use crate::cluster::dataset::Dataset;
use crate::cluster::netmodel::{NetSize, CONTAINER_OVERHEAD};
use crate::cluster::Cluster;
use crate::engine::EngineError;
use crate::runtime::{BandExtract, KernelBackend, NativeBackend};
use crate::sketch::GkCore;
use crate::{target_rank, Key};

/// Fused per-query results travelling through treeReduce together.
struct ExtractSet(Vec<BandExtract>);

impl NetSize for ExtractSet {
    fn net_bytes(&self) -> u64 {
        CONTAINER_OVERHEAD + self.0.iter().map(NetSize::net_bytes).sum::<u64>()
    }
}

/// Candidate slices for every still-open query (fallback round).
struct SliceSet(Vec<Vec<Key>>);

impl NetSize for SliceSet {
    fn net_bytes(&self) -> u64 {
        CONTAINER_OVERHEAD
            + self
                .0
                .iter()
                .map(|s| CONTAINER_OVERHEAD + 4 * s.len() as u64)
                .sum::<u64>()
    }
}

/// Result of a batched query.
#[derive(Debug, Clone)]
pub struct MultiOutcome {
    /// Exact value per requested quantile, same order as the input.
    pub values: Vec<Key>,
    pub report: crate::cluster::metrics::MetricsReport,
}

/// The full batched protocol — sketch round plus one fused multi-band
/// scan — through an explicit kernel backend. Resets the run ledger.
pub(crate) fn quantiles_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    data: &Dataset<Key>,
    qs: &[f64],
) -> Result<MultiOutcome, EngineError> {
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    if qs.is_empty() {
        return Err(EngineError::NoQuantiles);
    }
    cluster.reset_run();

    // ---- Round 1: one sketch, m pivots + m bands -------------------
    let sketch = build_global_sketch(cluster, data, params.variant, params.merge, params.epsilon)?;

    // ---- Round 2 (+3 fallback): one fused scan for all m queries ---
    quantiles_with_sketch_with(cluster, backend, params, data, &sketch, qs)
}

/// The batched post-sketch protocol against an **already-merged** global
/// sketch covering exactly `data`: one fused multi-band scan answers
/// every quantile (shared fallback round on overflow). Does NOT reset
/// the run ledger — the streaming query path calls this with cached
/// sketches so an m-quantile query costs one data scan.
pub(crate) fn quantiles_with_sketch_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    data: &Dataset<Key>,
    sketch: &GkCore,
    qs: &[f64],
) -> Result<MultiOutcome, EngineError> {
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    if qs.is_empty() {
        return Err(EngineError::NoQuantiles);
    }
    let n = data.len();
    if sketch.count != n {
        return Err(EngineError::Execution(format!(
            "sketch covers {} records, dataset holds {n}",
            sketch.count
        )));
    }
    let ks: Vec<u64> = qs.iter().map(|&q| target_rank(n, q)).collect();

    let queries: Vec<(Key, Key, Key)> = cluster.driver(|| {
        qs.iter()
            .zip(ks.iter())
            .map(|(&q, &k)| {
                let pivot = sketch.query_quantile(q).expect("nonempty sketch");
                let (lo, hi) = sketch.query_rank_bounds(k + 1).expect("nonempty sketch");
                (pivot, lo, hi)
            })
            .collect()
    });

    // ---- Round 2: one fused scan serving all m queries --------------
    cluster.broadcast(&queries);
    // budget against the looser of the engine's ε and the (possibly
    // cached, coarser) sketch's ε — see gk_select::select_with_sketch_with
    let budget_eps = params.epsilon.max(sketch.epsilon);
    let budget = params
        .candidate_budget
        .unwrap_or_else(|| default_candidate_budget(budget_eps, n));
    let qy = queries.clone();
    let pending = cluster.map_partitions(data, |part, _| {
        ExtractSet(backend.multi_band_extract(part, &qy, budget))
    })?;
    let mut merged = cluster
        .tree_reduce(pending, params.tree_depth, |a, b| {
            ExtractSet(
                a.0.into_iter()
                    .zip(b.0)
                    .map(|(x, y)| x.merge(y, budget))
                    .collect(),
            )
        })
        .expect("nonempty dataset");
    // band-efficiency ledger: each of the m fused queries ran under its
    // own 16εn+64 budget; shipped ≤ budget per query (merge truncates)
    cluster.metrics.band_candidates += merged
        .0
        .iter()
        .map(|e| e.candidates.len() as u64)
        .sum::<u64>();
    cluster.metrics.band_budget += (budget * queries.len()) as u64;

    // per-query resolution: eq-run exit, band resolve, or open with Δk
    let mut values: Vec<Option<Key>> = vec![None; qs.len()];
    let mut deltas: Vec<i64> = vec![0; qs.len()];
    let resolved: Vec<Option<Key>> = cluster.driver(|| {
        merged
            .0
            .iter_mut()
            .zip(queries.iter())
            .zip(ks.iter())
            .map(|((ext, &(pivot, lo, hi)), &k)| {
                let (lt, eq) = (ext.pivot.lt, ext.pivot.eq);
                if lt <= k && k < lt + eq {
                    return Some(pivot);
                }
                resolve_band(ext, lo, hi, k)
            })
            .collect()
    });
    for (i, v) in resolved.into_iter().enumerate() {
        match v {
            Some(v) => values[i] = Some(v),
            None => {
                let ext = &merged.0[i];
                deltas[i] = pivot_delta(ext.pivot.lt, ext.pivot.eq, ks[i]);
            }
        }
    }

    if values.iter().all(Option::is_some) {
        // all m answers out of the one fused scan — 2 rounds
        let out = values.into_iter().map(|v| v.expect("set")).collect();
        return Ok(MultiOutcome {
            values: out,
            report: run_report("GK Multi-Select", true, cluster, n),
        });
    }

    // ---- Round 3 (fallback): classic extraction for open queries ---
    cluster.broadcast(&deltas);
    let open: Vec<usize> = (0..qs.len()).filter(|&i| values[i].is_none()).collect();
    let open_in_closure = open.clone();
    let pv: Vec<Key> = queries.iter().map(|&(p, _, _)| p).collect();
    let ds = deltas.clone();
    let pending = cluster.map_partitions(data, |part, _| {
        SliceSet(
            open_in_closure
                .iter()
                .map(|&i| second_pass(part, pv[i], ds[i]))
                .collect(),
        )
    })?;
    let merged = cluster
        .tree_reduce(pending, params.tree_depth, |a, b| {
            SliceSet(
                a.0.into_iter()
                    .zip(b.0)
                    .zip(open.iter())
                    .map(|((sa, sb), &i)| reduce_slices(sa, sb, deltas[i]))
                    .collect(),
            )
        })
        .expect("nonempty");

    let resolved: Vec<Option<Key>> = cluster.driver(|| {
        merged
            .0
            .iter()
            .zip(open.iter())
            .map(|(slice, &i)| {
                if deltas[i] < 0 {
                    slice.iter().min().copied()
                } else {
                    slice.iter().max().copied()
                }
            })
            .collect()
    });
    for (&i, v) in open.iter().zip(resolved) {
        values[i] = Some(v.ok_or(EngineError::BudgetOverflow {
            fallback_used: true,
        })?);
    }

    Ok(MultiOutcome {
        values: values.into_iter().map(|v| v.expect("set")).collect(),
        report: run_report("GK Multi-Select", true, cluster, n),
    })
}

/// The pre-redesign batched driver, owning its own kernel backend. Kept
/// as a thin shim for one release — route `QuantileQuery::Multi` plans
/// through [`crate::engine::QuantileEngine::execute`] instead.
pub struct MultiSelect {
    pub params: GkSelectParams,
    backend: Box<dyn KernelBackend>,
}

impl MultiSelect {
    #[deprecated(
        since = "0.2.0",
        note = "build a `QuantileEngine` and execute `QuantileQuery::Multi(..)`"
    )]
    pub fn new(params: GkSelectParams) -> Self {
        Self {
            params,
            backend: Box::new(NativeBackend::new()),
        }
    }

    #[deprecated(
        since = "0.2.0",
        note = "use `EngineBuilder::kernel_backend` / `backend_name` instead"
    )]
    pub fn with_backend(params: GkSelectParams, backend: Box<dyn KernelBackend>) -> Self {
        Self { params, backend }
    }

    /// Active SIMD lane width of the backend's fused band scan (1 =
    /// scalar).
    pub fn simd_lane_width(&self) -> usize {
        self.backend.simd_lane_width()
    }

    /// Exact values for every quantile in `qs`, in 2 rounds (3 if any
    /// band overflows the candidate budget). Stamps this shim's own
    /// backend lane width to preserve the old report contract.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute(Source::Dataset(..), QuantileQuery::Multi(..))`"
    )]
    pub fn quantiles(
        &mut self,
        cluster: &mut Cluster,
        data: &Dataset<Key>,
        qs: &[f64],
    ) -> anyhow::Result<MultiOutcome> {
        let mut out = quantiles_with(cluster, self.backend.as_ref(), &self.params, data, qs)?;
        out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
        Ok(out)
    }

    /// The batched post-sketch protocol against a pre-merged sketch.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute(Source::Stream(..), QuantileQuery::Multi(..))`"
    )]
    pub fn quantiles_with_sketch(
        &mut self,
        cluster: &mut Cluster,
        data: &Dataset<Key>,
        sketch: &GkCore,
        qs: &[f64],
    ) -> anyhow::Result<MultiOutcome> {
        let mut out = quantiles_with_sketch_with(
            cluster,
            self.backend.as_ref(),
            &self.params,
            data,
            sketch,
            qs,
        )?;
        out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn run(dist: Distribution, n: u64, qs: &[f64]) -> MultiOutcome {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(55).generate(&mut c, n);
        let backend = NativeBackend::new();
        let out =
            quantiles_with(&mut c, &backend, &GkSelectParams::default(), &data, qs).unwrap();
        for (&q, &v) in qs.iter().zip(out.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, q).unwrap(), "{} q={q}", dist.label());
        }
        out
    }

    #[test]
    fn four_quantiles_two_rounds_one_scan() {
        let out = run(
            Distribution::Uniform,
            60_000,
            &[0.5, 0.9, 0.99, 0.999],
        );
        assert!(out.report.rounds <= 2, "rounds = {}", out.report.rounds);
        // m quantiles share the single fused post-sketch scan
        assert_eq!(out.report.data_scans, 2);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
    }

    #[test]
    fn all_distributions_exact() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            run(dist, 30_000, &[0.01, 0.25, 0.5, 0.75, 0.99]);
        }
    }

    #[test]
    fn single_quantile_degenerates_to_gk_select() {
        let out = run(Distribution::Uniform, 20_000, &[0.5]);
        assert_eq!(out.values.len(), 1);
        assert!(out.report.rounds <= 2);
    }

    #[test]
    fn duplicate_heavy_finishes_in_two_rounds() {
        // zipf: most quantiles land inside the heavy hitter's eq-run;
        // endpoint runs are counted, not extracted, so no overflow
        let out = run(Distribution::Zipf, 40_000, &[0.3, 0.5, 0.7]);
        assert!(out.report.rounds <= 2);
    }

    #[test]
    fn extreme_batch() {
        run(Distribution::Uniform, 10_000, &[0.0, 1.0, 0.5, 0.001, 0.999]);
    }

    #[test]
    fn zero_budget_batch_falls_back_exact() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Uniform.generator(56).generate(&mut c, 30_000);
        let backend = NativeBackend::new();
        let params = GkSelectParams {
            candidate_budget: Some(0),
            ..Default::default()
        };
        let qs = [0.25, 0.5, 0.75];
        let out = quantiles_with(&mut c, &backend, &params, &data, &qs).unwrap();
        for (&q, &v) in qs.iter().zip(out.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, q).unwrap(), "q={q}");
        }
        assert!(out.report.rounds <= 3);
    }

    #[test]
    fn rejects_empty_inputs() {
        let mut c = Cluster::new(ClusterConfig::local(1, 1));
        let backend = NativeBackend::new();
        let params = GkSelectParams::default();
        let data = Dataset::from_partitions(vec![vec![]]).unwrap();
        assert_eq!(
            quantiles_with(&mut c, &backend, &params, &data, &[0.5]).unwrap_err(),
            EngineError::EmptyInput
        );
        let data = Dataset::from_vec(vec![1, 2, 3], 1).unwrap();
        assert_eq!(
            quantiles_with(&mut c, &backend, &params, &data, &[]).unwrap_err(),
            EngineError::NoQuantiles
        );
    }
}
