//! GK Multi-Select: answer **m quantiles exactly in the same 3 rounds**.
//!
//! The paper's §V runs once per quantile query; its round structure,
//! however, batches for free — an extension the evaluation (Figs. 3–4's
//! `…50`/`…99` pairs) invites:
//!
//! 1. build/merge the sketch **once**, query all m pivots from it;
//! 2. one count pass classifies every partition against all m pivots
//!    (m linear scans fused into one task), one reduce returns all
//!    count triples;
//! 3. one extraction pass produces the m candidate slices, one
//!    treeReduce trims each side-by-side; the driver reads off all m
//!    exact values.
//!
//! Per-query marginal cost collapses to the two cheap passes; the sketch
//! (the dominant term) is shared. `repro` exposes it through the library
//! API; `examples/telemetry_pipeline.rs`-style monitoring is the use
//! case (p50/p90/p99/p999 of the same window).

use super::approx_quantile::{build_global_sketch, MergeStrategy, SketchVariant};
use super::gk_select::{reduce_slices, second_pass, GkSelectParams};
use super::{make_report, Outcome};
use crate::cluster::dataset::Dataset;
use crate::cluster::netmodel::{NetSize, CONTAINER_OVERHEAD};
use crate::cluster::Cluster;
use crate::runtime::{KernelBackend, NativeBackend};
use crate::{target_rank, Key};
use anyhow::{ensure, Result};

/// Candidate slices for every still-open query (wire-sized container).
struct SliceSet(Vec<Vec<Key>>);

impl NetSize for SliceSet {
    fn net_bytes(&self) -> u64 {
        CONTAINER_OVERHEAD
            + self
                .0
                .iter()
                .map(|s| CONTAINER_OVERHEAD + 4 * s.len() as u64)
                .sum::<u64>()
    }
}

/// Batched exact multi-quantile driver.
pub struct MultiSelect {
    pub params: GkSelectParams,
    backend: Box<dyn KernelBackend>,
}

/// Result of a batched query.
#[derive(Debug, Clone)]
pub struct MultiOutcome {
    /// Exact value per requested quantile, same order as the input.
    pub values: Vec<Key>,
    pub report: crate::cluster::metrics::MetricsReport,
}

impl MultiSelect {
    pub fn new(params: GkSelectParams) -> Self {
        Self {
            params,
            backend: Box::new(NativeBackend::new()),
        }
    }

    pub fn with_backend(params: GkSelectParams, backend: Box<dyn KernelBackend>) -> Self {
        Self { params, backend }
    }

    /// Exact values for every quantile in `qs`, in 3 rounds total.
    pub fn quantiles(
        &mut self,
        cluster: &mut Cluster,
        data: &Dataset<Key>,
        qs: &[f64],
    ) -> Result<MultiOutcome> {
        ensure!(!data.is_empty(), "empty dataset");
        ensure!(!qs.is_empty(), "no quantiles requested");
        cluster.reset_run();
        let n = data.len();
        let ks: Vec<u64> = qs.iter().map(|&q| target_rank(n, q)).collect();

        // ---- Round 1: one sketch, m pivots -----------------------------
        let sketch = build_global_sketch(
            cluster,
            data,
            self.params.variant,
            self.params.merge,
            self.params.epsilon,
        )?;
        let pivots: Vec<Key> = cluster.driver(|| {
            qs.iter()
                .map(|&q| sketch.query_quantile(q).expect("nonempty sketch"))
                .collect()
        });

        // ---- Round 2: fused count pass over all pivots ------------------
        cluster.broadcast(&pivots);
        let backend = self.backend.as_mut();
        let pv = pivots.clone();
        let pending = cluster.map_partitions(data, |part, _| {
            pv.iter()
                .map(|&p| {
                    let c = backend.count_pivot(part, p);
                    (c.lt, c.eq, c.gt)
                })
                .collect::<Vec<_>>()
        });
        let totals = cluster
            .reduce(pending, |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    x.0 += y.0;
                    x.1 += y.1;
                    x.2 += y.2;
                }
                a
            })
            .expect("nonempty");

        // per-query state: answered by the eq-run, or open with Δk
        let mut values: Vec<Option<Key>> = vec![None; qs.len()];
        let mut deltas: Vec<i64> = vec![0; qs.len()];
        for (i, (&k, &(lt, eq, _))) in ks.iter().zip(totals.iter()).enumerate() {
            if lt <= k && k < lt + eq {
                values[i] = Some(pivots[i]);
            } else {
                let approx_rank = if lt + eq <= k {
                    lt as i64 + eq as i64 - 1
                } else {
                    lt as i64
                };
                deltas[i] = k as i64 - approx_rank;
            }
        }

        if values.iter().all(Option::is_some) {
            let out = values.into_iter().map(|v| v.expect("set")).collect();
            let rep = make_report("GK Multi-Select", true, cluster, n, 0);
            return Ok(MultiOutcome {
                values: out,
                report: rep.report,
            });
        }

        // ---- Round 3: fused extraction + treeReduce ---------------------
        cluster.broadcast(&deltas);
        let seed = self.params.seed;
        let open: Vec<usize> = (0..qs.len()).filter(|&i| values[i].is_none()).collect();
        let open_in_closure = open.clone();
        let pv = pivots.clone();
        let ds = deltas.clone();
        let pending = cluster.map_partitions(data, |part, ctx| {
            SliceSet(
                open_in_closure
                    .iter()
                    .map(|&i| {
                        second_pass(part, pv[i], ds[i], seed ^ ((ctx.partition as u64) << 7))
                    })
                    .collect(),
            )
        });
        let mut salt = seed;
        let merged = cluster
            .tree_reduce(pending, self.params.tree_depth, |a, b| {
                salt = salt.wrapping_add(0x9E37);
                SliceSet(
                    a.0.into_iter()
                        .zip(b.0)
                        .zip(open.iter())
                        .map(|((sa, sb), &i)| reduce_slices(sa, sb, deltas[i], salt))
                        .collect(),
                )
            })
            .expect("nonempty");

        let resolved: Vec<Key> = cluster.driver(|| {
            merged
                .0
                .iter()
                .zip(open.iter())
                .map(|(slice, &i)| {
                    if deltas[i] < 0 {
                        *slice.iter().min().expect("nonempty slice")
                    } else {
                        *slice.iter().max().expect("nonempty slice")
                    }
                })
                .collect()
        });
        for (&i, v) in open.iter().zip(resolved) {
            values[i] = Some(v);
        }

        let rep = make_report("GK Multi-Select", true, cluster, n, 0);
        Ok(MultiOutcome {
            values: values.into_iter().map(|v| v.expect("set")).collect(),
            report: rep.report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn run(dist: Distribution, n: u64, qs: &[f64]) -> MultiOutcome {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(55).generate(&mut c, n);
        let mut alg = MultiSelect::new(GkSelectParams::default());
        let out = alg.quantiles(&mut c, &data, qs).unwrap();
        for (&q, &v) in qs.iter().zip(out.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, q).unwrap(), "{} q={q}", dist.label());
        }
        out
    }

    #[test]
    fn four_quantiles_three_rounds() {
        let out = run(
            Distribution::Uniform,
            60_000,
            &[0.5, 0.9, 0.99, 0.999],
        );
        assert!(out.report.rounds <= 3, "rounds = {}", out.report.rounds);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
    }

    #[test]
    fn all_distributions_exact() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            run(dist, 30_000, &[0.01, 0.25, 0.5, 0.75, 0.99]);
        }
    }

    #[test]
    fn single_quantile_degenerates_to_gk_select() {
        let out = run(Distribution::Uniform, 20_000, &[0.5]);
        assert_eq!(out.values.len(), 1);
        assert!(out.report.rounds <= 3);
    }

    #[test]
    fn duplicate_heavy_can_finish_in_two_rounds() {
        // zipf: most quantiles land inside the heavy hitter's eq-run
        let out = run(Distribution::Zipf, 40_000, &[0.3, 0.5, 0.7]);
        assert!(out.report.rounds <= 3);
    }

    #[test]
    fn extreme_batch() {
        run(Distribution::Uniform, 10_000, &[0.0, 1.0, 0.5, 0.001, 0.999]);
    }

    #[test]
    fn rejects_empty_inputs() {
        let mut c = Cluster::new(ClusterConfig::local(1, 1));
        let data = Dataset::from_partitions(vec![vec![]]);
        let mut alg = MultiSelect::new(GkSelectParams::default());
        assert!(alg.quantiles(&mut c, &data, &[0.5]).is_err());
        let data = Dataset::from_vec(vec![1, 2, 3], 1);
        assert!(alg.quantiles(&mut c, &data, &[]).is_err());
    }
}
