//! Count-and-discard distributed selection — the shared engine behind
//! AFS (§IV-B) and Jeffers Select (§IV-C).
//!
//! Per round: broadcast pivot → local Dutch partition + count + candidate
//! pivots from both sides → aggregate (treeReduce for AFS, collect for
//! Jeffers) → driver picks the side containing the target rank, discards
//! the other, and broadcasts the next pivot, which the executors supplied
//! from the *correct* side (the paper's trick that halves the number of
//! aggregations per pivot update).
//!
//! Because datasets are immutable, each round materializes the retained
//! side as a new persisted dataset — the `O(log n)` persists in Table V.

use super::{drive_plan, run_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::netmodel::NetSize;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::select::{dutch_partition, SplitMix64};
use crate::{target_rank, Key};
use anyhow::Result;

/// How per-round stats reach the driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggMode {
    /// log-depth treeReduce (AFS).
    TreeReduce,
    /// direct executor→driver collect (Jeffers).
    Collect,
}

/// Tuning knobs shared by both variants.
#[derive(Debug, Clone)]
pub struct CountDiscardParams {
    pub seed: u64,
    /// Safety valve on the `O(log n)` expected rounds.
    pub max_rounds: u64,
    /// treeReduce depth override (AFS only).
    pub tree_depth: Option<usize>,
}

impl Default for CountDiscardParams {
    fn default() -> Self {
        Self {
            seed: 0xAF5_0001,
            max_rounds: 10_000,
            tree_depth: None,
        }
    }
}

/// Per-partition round message: counts + one uniform candidate from each
/// side of the pivot, weighted by side population (reservoir merge keeps
/// global uniformity).
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    pub lt: u64,
    pub eq: u64,
    pub gt: u64,
    pub cand_lo: Option<(Key, u64)>,
    pub cand_hi: Option<(Key, u64)>,
}

impl NetSize for RoundStats {
    fn net_bytes(&self) -> u64 {
        3 * 8 + self.cand_lo.net_bytes() + self.cand_hi.net_bytes()
    }
}

/// Weighted reservoir combine of two optional candidates.
fn merge_cand(
    a: Option<(Key, u64)>,
    b: Option<(Key, u64)>,
    rng: &mut SplitMix64,
) -> Option<(Key, u64)> {
    match (a, b) {
        (None, x) | (x, None) => x,
        (Some((va, wa)), Some((vb, wb))) => {
            let total = wa + wb;
            let pick_a = (rng.next_u64() % total.max(1)) < wa;
            Some((if pick_a { va } else { vb }, total))
        }
    }
}

fn merge_stats(a: RoundStats, b: RoundStats, rng: &mut SplitMix64) -> RoundStats {
    RoundStats {
        lt: a.lt + b.lt,
        eq: a.eq + b.eq,
        gt: a.gt + b.gt,
        cand_lo: merge_cand(a.cand_lo, b.cand_lo, rng),
        cand_hi: merge_cand(a.cand_hi, b.cand_hi, rng),
    }
}

/// The iterative engine. Generic over aggregation mode; AFS and Jeffers
/// are thin wrappers.
pub struct CountDiscardSelect {
    pub label: &'static str,
    pub mode: AggMode,
    pub params: CountDiscardParams,
}

impl CountDiscardSelect {
    pub fn new(label: &'static str, mode: AggMode, params: CountDiscardParams) -> Self {
        Self {
            label,
            mode,
            params,
        }
    }

    /// Round 0: a uniform random element as the initial pivot (one
    /// collect round, reservoir over partitions).
    fn initial_pivot(&self, cluster: &mut Cluster, data: &Dataset<Key>) -> Result<Key, EngineError> {
        let seed = self.params.seed;
        let pending = cluster.map_partitions(data, |part, ctx| {
            if part.is_empty() {
                None
            } else {
                let mut rng = SplitMix64::new(seed ^ (ctx.partition as u64) << 3);
                Some((part[rng.below(part.len())], part.len() as u64))
            }
        })?;
        let cands = cluster.collect(pending);
        let mut rng = SplitMix64::new(seed ^ 0xD1CE);
        let picked = cluster.driver(|| {
            cands
                .into_iter()
                .flatten()
                .fold(None, |acc, c| merge_cand(acc, Some(c), &mut rng))
        });
        picked.map(|(v, _)| v).ok_or(EngineError::EmptyInput)
    }

    /// The full count-discard protocol. Resets the run ledger.
    pub(crate) fn quantile_with(
        &self,
        cluster: &mut Cluster,
        data: &Dataset<Key>,
        q: f64,
    ) -> Result<Outcome, EngineError> {
        if data.is_empty() {
            return Err(EngineError::EmptyInput);
        }
        cluster.reset_run();
        let n = data.len();
        let mut k = target_rank(n, q);
        let mut pivot = self.initial_pivot(cluster, data)?;
        let mut work = data.clone();

        for round in 0..self.params.max_rounds {
            cluster.broadcast(&pivot);

            // local Dutch partition + counts + candidates; the partitioned
            // copy rides along executor-side for the discard step
            let seed = self.params.seed ^ (round << 32);
            let pending = cluster.map_partitions(&work, |part, ctx| {
                let mut a = part.to_vec();
                let split = dutch_partition(&mut a, pivot);
                let mut rng =
                    SplitMix64::new(seed ^ ((ctx.partition as u64) << 8) ^ 0xBEEF);
                let n_hi = a.len() - split.gt;
                let cand_lo = (split.lt > 0)
                    .then(|| (a[rng.below(split.lt)], split.lt as u64));
                let cand_hi =
                    (n_hi > 0).then(|| (a[split.gt + rng.below(n_hi)], n_hi as u64));
                (
                    RoundStats {
                        lt: split.lt as u64,
                        eq: (split.gt - split.lt) as u64,
                        gt: n_hi as u64,
                        cand_lo,
                        cand_hi,
                    },
                    (a, split),
                )
            })?;
            let (stats_p, parts_p) = pending.unzip();

            // aggregate — the round's driver barrier
            let mut rng = SplitMix64::new(seed ^ 0xA66);
            let agg = match self.mode {
                AggMode::TreeReduce => cluster
                    .tree_reduce(stats_p, self.params.tree_depth, |a, b| {
                        merge_stats(a, b, &mut rng)
                    })
                    .expect("nonempty"),
                AggMode::Collect => {
                    let all = cluster.collect(stats_p);
                    cluster.driver(|| {
                        all.into_iter()
                            .reduce(|a, b| merge_stats(a, b, &mut rng))
                            .expect("nonempty")
                    })
                }
            };

            // the partitioned copy is persisted for the discard
            cluster.persist_bytes(work.data_bytes());

            if agg.lt <= k && k < agg.lt + agg.eq {
                return Ok(Outcome {
                    value: pivot,
                    report: run_report(self.label, true, cluster, n),
                });
            }

            if k < agg.lt {
                // discard everything ≥ pivot; target stays at rank k
                pivot = agg
                    .cand_lo
                    .ok_or_else(|| {
                        EngineError::Execution("no candidate below pivot".to_string())
                    })?
                    .0;
                work = Dataset::from_partitions(
                    parts_p
                        .values
                        .into_iter()
                        .map(|(a, split)| a[..split.lt].to_vec())
                        .collect(),
                )
                .expect("partition count preserved by discard");
            } else {
                // discard everything ≤ pivot; rebase the target rank
                k -= agg.lt + agg.eq;
                pivot = agg
                    .cand_hi
                    .ok_or_else(|| {
                        EngineError::Execution("no candidate above pivot".to_string())
                    })?
                    .0;
                work = Dataset::from_partitions(
                    parts_p
                        .values
                        .into_iter()
                        .map(|(a, split)| a[split.gt..].to_vec())
                        .collect(),
                )
                .expect("partition count preserved by discard");
            }
        }
        Err(EngineError::Execution(format!(
            "{} did not converge within {} rounds",
            self.label, self.params.max_rounds
        )))
    }

    /// One exact quantile — the pre-redesign entry point.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute` with `AlgoChoice::Afs` / `AlgoChoice::Jeffers`"
    )]
    pub fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        Ok(self.quantile_with(cluster, data, q)?)
    }
}

impl QuantileAlgorithm for CountDiscardSelect {
    fn name(&self) -> &'static str {
        self.label
    }

    fn exact(&self) -> bool {
        true
    }

    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let data = ctx.data;
        drive_plan(ctx.cluster, data, query, |cluster, q| {
            self.quantile_with(cluster, data, q)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn check(mode: AggMode, dist: Distribution, n: u64, q: f64) -> Outcome {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(17).generate(&mut c, n);
        let truth = oracle_quantile(&data, q).unwrap();
        let alg = CountDiscardSelect::new("cd", mode, CountDiscardParams::default());
        let out = alg.quantile_with(&mut c, &data, q).unwrap();
        assert_eq!(out.value, truth, "{mode:?} {} q={q}", dist.label());
        out
    }

    #[test]
    fn tree_reduce_exact_on_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            check(AggMode::TreeReduce, dist, 30_000, 0.5);
        }
    }

    #[test]
    fn collect_exact_on_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            check(AggMode::Collect, dist, 30_000, 0.99);
        }
    }

    #[test]
    fn rounds_are_logarithmic() {
        let out = check(AggMode::TreeReduce, Distribution::Uniform, 100_000, 0.5);
        // expected ~log2(1e5)≈17 rounds (+1 init); generous x4 bound
        assert!(
            (2..=80).contains(&out.report.rounds),
            "rounds = {}",
            out.report.rounds
        );
        assert!(out.report.persists > 0, "count-discard must persist");
        assert_eq!(out.report.shuffles, 0);
    }

    #[test]
    fn rounds_grow_with_n() {
        let small = check(AggMode::TreeReduce, Distribution::Uniform, 1_000, 0.5);
        let big = check(AggMode::TreeReduce, Distribution::Uniform, 300_000, 0.5);
        assert!(
            big.report.rounds > small.report.rounds,
            "rounds {} !> {}",
            big.report.rounds,
            small.report.rounds
        );
    }

    #[test]
    fn extreme_quantiles_exact() {
        check(AggMode::TreeReduce, Distribution::Uniform, 10_000, 0.0);
        check(AggMode::TreeReduce, Distribution::Uniform, 10_000, 1.0);
        check(AggMode::Collect, Distribution::Uniform, 10_000, 0.0);
    }

    #[test]
    fn all_equal_terminates_immediately() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let data = Dataset::from_vec(vec![42; 10_000], 4).unwrap();
        let alg =
            CountDiscardSelect::new("cd", AggMode::TreeReduce, CountDiscardParams::default());
        let out = alg.quantile_with(&mut c, &data, 0.5).unwrap();
        assert_eq!(out.value, 42);
        // init round + 1 iteration
        assert!(out.report.rounds <= 2);
    }

    #[test]
    fn singleton() {
        let mut c = Cluster::new(ClusterConfig::local(1, 1));
        let data = Dataset::from_vec(vec![7], 1).unwrap();
        let alg =
            CountDiscardSelect::new("cd", AggMode::Collect, CountDiscardParams::default());
        assert_eq!(alg.quantile_with(&mut c, &data, 0.5).unwrap().value, 7);
    }

    #[test]
    fn round_stats_netsize() {
        let s = RoundStats {
            lt: 1,
            eq: 2,
            gt: 3,
            cand_lo: Some((5, 1)),
            cand_hi: None,
        };
        assert_eq!(s.net_bytes(), 24 + 13 + 1);
    }
}
