//! In-repo substrates replacing the crates an online build would pull in.
//!
//! The reproduction environment is fully offline (only `xla` + `anyhow`
//! are vendored), so the supporting machinery a production repo normally
//! imports is implemented here:
//!
//! * [`minitoml`] — TOML subset reader/writer for the config system
//! * [`minijson`] — JSON subset reader for `artifacts/manifest.json`
//! * [`cli`] — declarative-ish flag parser for the `repro` launcher
//! * [`benchkit`] — warmup/sample micro-bench harness (criterion stand-in)
//! * [`propkit`] — seeded property-testing harness (proptest stand-in)

pub mod benchkit;
pub mod cli;
pub mod minijson;
pub mod minitoml;
pub mod propkit;
