//! Micro-bench harness (criterion stand-in for the offline build).
//!
//! Warmup + fixed sample count, reports min/mean/p50/max and per-element
//! throughput. Benches are plain `harness = false` binaries that call
//! [`Bench::run`] per case; output is grep-friendly one-line-per-case so
//! `cargo bench | tee bench_output.txt` stays diffable.

use std::time::Instant;

/// One benchmark group runner.
pub struct Bench {
    group: String,
    warmup_iters: u32,
    sample_iters: u32,
}

/// Result of one case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub max_s: f64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Self {
            group: group.to_string(),
            warmup_iters: 2,
            sample_iters: 10,
        }
    }

    pub fn samples(mut self, n: u32) -> Self {
        self.sample_iters = n.max(1);
        self
    }

    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Run one case; `f` must return something observable so the work is
    /// not optimized away.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.sample_iters as usize);
        for _ in 0..self.sample_iters {
            let start = Instant::now();
            std::hint::black_box(f());
            times.push(start.elapsed().as_secs_f64());
        }
        times.sort_by(f64::total_cmp);
        let sample = Sample {
            name: format!("{}/{name}", self.group),
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            min_s: times[0],
            p50_s: times[times.len() / 2],
            max_s: *times.last().expect("nonempty"),
        };
        println!(
            "bench {:<48} mean {:>12} p50 {:>12} min {:>12} max {:>12}",
            sample.name,
            fmt_s(sample.mean_s),
            fmt_s(sample.p50_s),
            fmt_s(sample.min_s),
            fmt_s(sample.max_s),
        );
        sample
    }

    /// Like [`run`], also reporting elements/second.
    pub fn run_throughput<T>(&self, name: &str, elements: u64, f: impl FnMut() -> T) -> Sample {
        let s = self.run(name, f);
        let eps = elements as f64 / s.mean_s;
        println!(
            "bench {:<48} throughput {:>10.1} Melem/s",
            s.name,
            eps / 1e6
        );
        s
    }
}

/// Human-scaled seconds.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench::new("test").samples(3).warmup(0);
        let s = b.run("noop", || 42);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.max_s);
        assert_eq!(s.name, "test/noop");
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_s(5e-9).contains("ns"));
        assert!(fmt_s(5e-5).contains("µs"));
        assert!(fmt_s(5e-2).contains("ms"));
        assert!(fmt_s(5.0).contains(" s"));
    }
}
