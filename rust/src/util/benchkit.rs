//! Micro-bench harness (criterion stand-in for the offline build).
//!
//! Warmup + fixed sample count, reports min/mean/p50/max and per-element
//! throughput. Benches are plain `harness = false` binaries that call
//! [`Bench::run`] per case; output is grep-friendly one-line-per-case so
//! `cargo bench | tee bench_output.txt` stays diffable.

use std::time::Instant;

/// One benchmark group runner.
pub struct Bench {
    group: String,
    warmup_iters: u32,
    sample_iters: u32,
}

/// Result of one case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub max_s: f64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Self {
            group: group.to_string(),
            warmup_iters: 2,
            sample_iters: 10,
        }
    }

    pub fn samples(mut self, n: u32) -> Self {
        self.sample_iters = n.max(1);
        self
    }

    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Run one case; `f` must return something observable so the work is
    /// not optimized away.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.sample_iters as usize);
        for _ in 0..self.sample_iters {
            let start = Instant::now();
            std::hint::black_box(f());
            times.push(start.elapsed().as_secs_f64());
        }
        times.sort_by(f64::total_cmp);
        let sample = Sample {
            name: format!("{}/{name}", self.group),
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            min_s: times[0],
            p50_s: times[times.len() / 2],
            max_s: *times.last().expect("nonempty"),
        };
        println!(
            "bench {:<48} mean {:>12} p50 {:>12} min {:>12} max {:>12}",
            sample.name,
            fmt_s(sample.mean_s),
            fmt_s(sample.p50_s),
            fmt_s(sample.min_s),
            fmt_s(sample.max_s),
        );
        sample
    }

    /// Like [`run`], also reporting elements/second.
    pub fn run_throughput<T>(&self, name: &str, elements: u64, f: impl FnMut() -> T) -> Sample {
        let s = self.run(name, f);
        let eps = elements as f64 / s.mean_s;
        println!(
            "bench {:<48} throughput {:>10.1} Melem/s",
            s.name,
            eps / 1e6
        );
        s
    }
}

/// Minimal JSON value for machine-readable bench artifacts
/// (`BENCH_*.json`): the write-side complement of `util::minijson`, so
/// perf trajectories can be diffed across PRs without a serde
/// dependency.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonVal {
    Bool(bool),
    U64(u64),
    F64(f64),
    Str(String),
    Arr(Vec<JsonVal>),
    Obj(Vec<(String, JsonVal)>),
}

impl JsonVal {
    pub fn obj(fields: Vec<(&str, JsonVal)>) -> JsonVal {
        JsonVal::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn render(&self) -> String {
        match self {
            JsonVal::Bool(b) => b.to_string(),
            JsonVal::U64(u) => u.to_string(),
            JsonVal::F64(f) => {
                if f.is_finite() {
                    // round-trippable, JSON-legal float formatting
                    format!("{f:?}")
                } else {
                    "null".to_string()
                }
            }
            JsonVal::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
                out
            }
            JsonVal::Arr(items) => {
                let body: Vec<String> = items.iter().map(JsonVal::render).collect();
                format!("[{}]", body.join(","))
            }
            JsonVal::Obj(fields) => {
                let body: Vec<String> = fields
                    .iter()
                    .map(|(k, v)| format!("{}:{}", JsonVal::Str(k.clone()).render(), v.render()))
                    .collect();
                format!("{{{}}}", body.join(","))
            }
        }
    }
}

/// Write a bench artifact to `path` (pretty enough to diff: one trailing
/// newline, compact body).
pub fn write_json(path: &std::path::Path, value: &JsonVal) -> std::io::Result<()> {
    std::fs::write(path, value.render() + "\n")
}

/// Human-scaled seconds.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench::new("test").samples(3).warmup(0);
        let s = b.run("noop", || 42);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.max_s);
        assert_eq!(s.name, "test/noop");
    }

    #[test]
    fn json_renders_and_roundtrips_through_minijson() {
        let v = JsonVal::obj(vec![
            ("algorithm", JsonVal::Str("GK Select".into())),
            ("rounds", JsonVal::U64(2)),
            ("elapsed_s", JsonVal::F64(0.125)),
            ("exact", JsonVal::Bool(true)),
            ("scans", JsonVal::Arr(vec![JsonVal::U64(1), JsonVal::U64(2)])),
        ]);
        let text = v.render();
        let parsed = crate::util::minijson::parse(&text).unwrap();
        assert_eq!(parsed.get("rounds").unwrap().as_u64(), Some(2));
        assert_eq!(parsed.get("algorithm").unwrap().as_str(), Some("GK Select"));
    }

    #[test]
    fn json_escapes_strings() {
        let v = JsonVal::Str("a\"b\\c\nd".into());
        assert_eq!(v.render(), r#""a\"b\\c\nd""#);
        assert!(crate::util::minijson::parse(&v.render()).is_ok());
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_s(5e-9).contains("ns"));
        assert!(fmt_s(5e-5).contains("µs"));
        assert!(fmt_s(5e-2).contains("ms"));
        assert!(fmt_s(5.0).contains(" s"));
    }
}
