//! Tiny flag parser for the `repro` launcher: subcommands +
//! `--flag value` / `--flag` booleans, with typed getters, `--help`
//! generation, and unknown-flag rejection.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed invocation: subcommand path + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// e.g. `["bench", "fig"]`.
    pub path: Vec<String>,
    flags: BTreeMap<String, String>,
    /// flags present without a value (`--verify`).
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: leading bare words become the subcommand path,
    /// `--key value` and `--switch` populate the maps.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().expect("peeked");
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                // bare words extend the subcommand path wherever they
                // appear, so global flags may precede the subcommand
                // (`repro --backend pjrt quantile ...`)
                out.path.push(a);
            }
        }
        Ok(out)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => parse_u64(v).with_context(|| format!("--{name} {v:?}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?}")),
        }
    }

    /// Reject any flag not in `known` (catches typos loudly).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys().chain(self.switches.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (expected one of: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

/// `u64` with `1e9` / `10_000` / `2^20` conveniences — experiment sizes
/// read naturally on the command line.
pub fn parse_u64(s: &str) -> Result<u64> {
    let cleaned = s.replace('_', "");
    if let Ok(v) = cleaned.parse::<u64>() {
        return Ok(v);
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
            return Ok(f as u64);
        }
    }
    if let Some((base, exp)) = cleaned.split_once('^') {
        let b: u64 = base.parse()?;
        let e: u32 = exp.parse()?;
        return Ok(b.pow(e));
    }
    bail!("cannot parse {s:?} as a count")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_path_and_flags() {
        let a = args(&["bench", "fig", "--nodes", "30", "--verify"]);
        assert_eq!(a.path, vec!["bench", "fig"]);
        assert_eq!(a.usize_or("nodes", 10).unwrap(), 30);
        assert!(a.has("verify"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn eq_form() {
        let a = args(&["quantile", "--n=1e6", "--q=0.99"]);
        assert_eq!(a.u64_or("n", 0).unwrap(), 1_000_000);
        assert_eq!(a.f64_or("q", 0.5).unwrap(), 0.99);
    }

    #[test]
    fn scientific_and_underscore_counts() {
        assert_eq!(parse_u64("1e9").unwrap(), 1_000_000_000);
        assert_eq!(parse_u64("10_000").unwrap(), 10_000);
        assert_eq!(parse_u64("2^20").unwrap(), 1 << 20);
        assert!(parse_u64("1.5").is_err());
        assert!(parse_u64("abc").is_err());
    }

    #[test]
    fn unknown_flag_rejection() {
        let a = args(&["x", "--good", "1", "--bad", "2"]);
        assert!(a.ensure_known(&["good"]).is_err());
        assert!(a.ensure_known(&["good", "bad"]).is_ok());
    }

    #[test]
    fn global_flags_before_subcommand() {
        let a = args(&["--backend", "pjrt", "quantile", "--n", "5"]);
        assert_eq!(a.path, vec!["quantile"]);
        assert_eq!(a.str_or("backend", "native"), "pjrt");
        assert_eq!(a.u64_or("n", 0).unwrap(), 5);
    }
}
