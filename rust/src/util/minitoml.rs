//! Minimal TOML subset for the config system.
//!
//! Supports what `repro.toml` needs and nothing more: `[section]`
//! headers, `key = value` with string / integer / float / boolean
//! values, `#` comments, and blank lines. Unknown keys are preserved in
//! the parse result so callers can reject or ignore them explicitly.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section → key → value`; keys outside any section land in `""`.
pub type Document = BTreeMap<String, BTreeMap<String, Value>>;

fn parse_value(raw: &str, line_no: usize) -> Result<Value> {
    let raw = raw.trim();
    if raw.is_empty() {
        bail!("line {line_no}: empty value");
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .with_context(|| format!("line {line_no}: unterminated string"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {line_no}: cannot parse value {raw:?}")
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc: Document = BTreeMap::new();
    let mut section = String::new();
    for (i, line) in text.lines().enumerate() {
        let line_no = i + 1;
        // strip comments outside strings (strings in our subset never
        // contain '#')
        let line = match line.find('#') {
            Some(pos) if !line[..pos].contains('"') || line[..pos].matches('"').count() % 2 == 0 => {
                &line[..pos]
            }
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .with_context(|| format!("line {line_no}: unterminated section header"))?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {line_no}: expected key = value"))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {line_no}: empty key");
        }
        doc.entry(section.clone())
            .or_default()
            .insert(key.to_string(), parse_value(value, line_no)?);
    }
    Ok(doc)
}

/// Serialize a document in deterministic order.
pub fn serialize(doc: &Document) -> String {
    let mut out = String::new();
    // root keys first
    if let Some(root) = doc.get("") {
        for (k, v) in root {
            out.push_str(&format!("{k} = {}\n", format_value(v)));
        }
        if !root.is_empty() {
            out.push('\n');
        }
    }
    for (section, table) in doc {
        if section.is_empty() {
            continue;
        }
        out.push_str(&format!("[{section}]\n"));
        for (k, v) in table {
            out.push_str(&format!("{k} = {}\n", format_value(v)));
        }
        out.push('\n');
    }
    out
}

fn format_value(v: &Value) -> String {
    match v {
        Value::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 && f.abs() < 1e15 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Bool(b) => b.to_string(),
    }
}

/// Typed getters with defaulting — the pattern the config loader uses.
pub struct Section<'a>(pub Option<&'a BTreeMap<String, Value>>);

impl<'a> Section<'a> {
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.0
            .and_then(|t| t.get(key))
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.0.and_then(|t| t.get(key)).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.0
            .and_then(|t| t.get(key))
            .and_then(Value::as_float)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.0.and_then(|t| t.get(key)).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn int_opt(&self, key: &str) -> Option<i64> {
        self.0.and_then(|t| t.get(key)).and_then(Value::as_int)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
# top comment
backend = "native"

[cluster]
nodes = 10          # trailing comment
compute_scale = 1.5
enabled = true
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["backend"], Value::Str("native".into()));
        assert_eq!(doc["cluster"]["nodes"], Value::Int(10));
        assert_eq!(doc["cluster"]["compute_scale"], Value::Float(1.5));
        assert_eq!(doc["cluster"]["enabled"], Value::Bool(true));
    }

    #[test]
    fn roundtrip() {
        let mut doc: Document = BTreeMap::new();
        doc.entry("".into())
            .or_default()
            .insert("backend".into(), Value::Str("pjrt".into()));
        doc.entry("net".into())
            .or_default()
            .insert("latency_us".into(), Value::Float(200.0));
        let text = serialize(&doc);
        let back = parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("= 3").is_err());
        assert!(parse("key = what?").is_err());
    }

    #[test]
    fn section_getters_default() {
        let doc = parse("[a]\nx = 3\ny = 2.5\nname = \"z\"\nflag = false\n").unwrap();
        let s = Section(doc.get("a"));
        assert_eq!(s.int_or("x", 0), 3);
        assert_eq!(s.float_or("y", 0.0), 2.5);
        assert_eq!(s.float_or("x", 0.0), 3.0, "ints widen to float");
        assert_eq!(s.str_or("name", "d"), "z");
        assert!(!s.bool_or("flag", true));
        assert_eq!(s.int_or("missing", 9), 9);
        let none = Section(doc.get("nope"));
        assert_eq!(none.int_or("x", 7), 7);
    }

    #[test]
    fn strings_with_escapes() {
        let doc = parse(r#"s = "a\"b\\c""#).unwrap();
        assert_eq!(doc[""]["s"], Value::Str("a\"b\\c".into()));
    }
}
