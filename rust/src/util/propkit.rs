//! Seeded property-testing harness (proptest stand-in for the offline
//! build).
//!
//! A property runs `cases` times against values drawn from composable
//! generators. On failure the harness re-reports the seed so the exact
//! case replays (`PROPKIT_SEED=<n> cargo test ...`). No shrinking — cases
//! are kept small instead.

use crate::select::SplitMix64;

/// Draw source handed to generators.
pub struct Gen<'a> {
    rng: &'a mut SplitMix64,
}

impl<'a> Gen<'a> {
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u64;
        (lo as i64 + (self.rng.next_u64() % span) as i64) as i32
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.rng.next_u64() % (hi - lo + 1) as u64) as usize
    }

    pub fn f64_unit(&mut self) -> f64 {
        (self.rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of `len ∈ [min_len, max_len]` values from `f`.
    pub fn vec_i32(&mut self, min_len: usize, max_len: usize, lo: i32, hi: i32) -> Vec<i32> {
        let len = self.usize_in(min_len, max_len);
        (0..len).map(|_| self.i32_in(lo, hi)).collect()
    }
}

/// Run `property` for `cases` seeded cases; panics with the failing seed.
pub fn check(test_name: &str, cases: u64, mut property: impl FnMut(&mut Gen)) {
    // PROPKIT_SEED is a test-harness replay knob, not engine
    // configuration — the one env read exempt from the GK-I2
    // centralization rule (docs/INVARIANTS.md).
    let base_seed = std::env::var("PROPKIT_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let (start, count) = match base_seed {
        Some(s) => (s, 1), // replay exactly one case
        None => (0xC0FFEE ^ fxhash(test_name), cases),
    };
    for i in 0..count {
        let seed = start.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = SplitMix64::new(seed);
        let mut g = Gen { rng: &mut rng };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(panic) = result {
            eprintln!("\npropkit: {test_name} failed at case {i} — replay with PROPKIT_SEED={seed}");
            std::panic::resume_unwind(panic);
        }
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 200, |g| {
            let v = g.i32_in(-5, 5);
            assert!((-5..=5).contains(&v));
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_unit();
            assert!((0.0..1.0).contains(&f));
            let xs = g.vec_i32(2, 10, 0, 1);
            assert!(xs.len() >= 2 && xs.len() <= 10);
            assert!(xs.iter().all(|&x| x == 0 || x == 1));
        });
    }

    #[test]
    fn deterministic_per_name() {
        let mut a = Vec::new();
        check("det", 5, |g| a.push(g.u64()));
        let mut b = Vec::new();
        check("det", 5, |g| b.push(g.u64()));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check("fail", 10, |g| {
            assert!(g.i32_in(0, 100) > 150, "impossible");
        });
    }
}
