//! Minimal JSON reader for `artifacts/manifest.json`.
//!
//! Full JSON value grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) with a recursive-descent parser; no
//! serialization (the python side writes the manifest).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input at {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.peek()?;
        if got != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                got as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, text: &str, value: Json) -> Result<Json> {
        self.skip_ws();
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        self.skip_ws();
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| {
            anyhow::anyhow!("bad number {s:?} at byte {start}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        // accumulate raw bytes: the input is UTF-8 and multibyte sequences
        // must pass through untouched
        let mut buf: Vec<u8> = Vec::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                bail!("unterminated string");
            };
            self.pos += 1;
            match b {
                b'"' => {
                    return String::from_utf8(buf).map_err(|_| anyhow::anyhow!("invalid utf-8"))
                }
                b'\\' => {
                    let Some(&e) = self.bytes.get(self.pos) else {
                        bail!("unterminated escape");
                    };
                    self.pos += 1;
                    let push_char = |c: char, buf: &mut Vec<u8>| {
                        let mut tmp = [0u8; 4];
                        buf.extend_from_slice(c.encode_utf8(&mut tmp).as_bytes());
                    };
                    match e {
                        b'"' => buf.push(b'"'),
                        b'\\' => buf.push(b'\\'),
                        b'/' => buf.push(b'/'),
                        b'n' => buf.push(b'\n'),
                        b't' => buf.push(b'\t'),
                        b'r' => buf.push(b'\r'),
                        b'b' => buf.push(8),
                        b'f' => buf.push(12),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes.get(self.pos..self.pos + 4).unwrap_or(b""),
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            push_char(char::from_u32(cp).unwrap_or('\u{fffd}'), &mut buf);
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                other => buf.push(other),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' found '{}'", other as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            map.insert(key, self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}' found '{}'", other as char),
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = parse(
            r#"{"buf_len":131072,"chunk":16384,"dtype":"i32",
                "artifacts":{"count_pivot":{"file":"count_pivot.hlo.txt","bytes":7146}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("buf_len").unwrap().as_u64(), Some(131072));
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("i32"));
        let a = j.get("artifacts").unwrap().get("count_pivot").unwrap();
        assert_eq!(a.get("file").unwrap().as_str(), Some("count_pivot.hlo.txt"));
    }

    #[test]
    fn parses_all_value_kinds() {
        let j = parse(r#"{"a":[1, -2.5, true, false, null, "s\n\"q\""], "b":{}}"#).unwrap();
        let Json::Arr(items) = j.get("a").unwrap() else {
            panic!()
        };
        assert_eq!(items.len(), 6);
        assert_eq!(items[1], Json::Num(-2.5));
        assert_eq!(items[5], Json::Str("s\n\"q\"".into()));
        assert_eq!(j.get("b").unwrap().as_obj().unwrap().len(), 0);
    }

    #[test]
    fn unicode_escape() {
        let j = parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("3").unwrap().as_u64(), Some(3));
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("2.5").unwrap(), Json::Num(2.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
    }
}
