//! Spark 3.5.5's GK variant (`QuantileSummaries`) — head-buffered.
//!
//! Values are appended to a `B = 50 000` array (`defaultHeadSize`); when
//! full, the buffer is *flushed*: sorted in `O(B log B)` and merged into
//! the summary in `O(B + |S|)`, then compressed if the summary exceeds
//! `compressThreshold = 10 000`. §IV-E1 shows this changes the executor
//! complexity to `O((n/P)·log B + (1/ε)(n/P)(1/B)·log(εn/P))` — the
//! `n log B` term the paper proves can never be amortized away for any
//! achievable dataset under Spark's defaults.

use super::{GkCore, QuantileSketch};
use crate::Key;

/// Spark's default head buffer capacity.
pub const DEFAULT_HEAD_SIZE: usize = 50_000;
/// Spark's default compress trigger.
pub const DEFAULT_COMPRESS_THRESHOLD: usize = 10_000;

/// Head-buffered GK summary, faithful to Spark 3.5.5 defaults.
#[derive(Debug, Clone)]
pub struct SparkGk {
    core: GkCore,
    head: Vec<Key>,
    head_capacity: usize,
    compress_threshold: usize,
}

impl SparkGk {
    pub fn new(epsilon: f64) -> Self {
        Self::with_params(epsilon, DEFAULT_HEAD_SIZE, DEFAULT_COMPRESS_THRESHOLD)
    }

    pub fn with_params(epsilon: f64, head_capacity: usize, compress_threshold: usize) -> Self {
        assert!(head_capacity > 0);
        Self {
            core: GkCore::new(epsilon),
            head: Vec::with_capacity(head_capacity.min(1 << 20)),
            head_capacity,
            compress_threshold,
        }
    }

    /// Sort + linear merge + conditional compress — `T_flush` (paper Eq. 3).
    fn flush(&mut self) {
        if self.head.is_empty() {
            return;
        }
        // §Perf L3.3: LSD radix beats comparison sort at B = 50 000
        crate::sort::radix::radix_sort_i32(&mut self.head);
        self.core.merge_sorted_batch(&self.head);
        self.head.clear();
        if self.core.samples.len() > self.compress_threshold {
            self.core.compress();
        }
    }

    pub fn core(&self) -> &GkCore {
        &self.core
    }

    pub fn into_core(mut self) -> GkCore {
        self.flush();
        self.core
    }

    pub fn from_core(core: GkCore, head_capacity: usize, compress_threshold: usize) -> Self {
        Self {
            core,
            head: Vec::new(),
            head_capacity,
            compress_threshold,
        }
    }

    /// Values currently buffered (observable for the variant benches).
    pub fn buffered(&self) -> usize {
        self.head.len()
    }
}

impl QuantileSketch for SparkGk {
    fn insert(&mut self, v: Key) {
        self.head.push(v);
        if self.head.len() >= self.head_capacity {
            self.flush();
        }
    }

    fn finalize(&mut self) {
        self.flush();
        self.core.compress();
    }

    fn merge(mut self, mut other: Self) -> Self {
        self.flush();
        other.flush();
        let head_capacity = self.head_capacity;
        let compress_threshold = self.compress_threshold;
        Self::from_core(
            self.core.merge_with(other.core),
            head_capacity,
            compress_threshold,
        )
    }

    fn query(&self, q: f64) -> Option<Key> {
        debug_assert!(
            self.head.is_empty(),
            "query before finalize misses buffered values"
        );
        self.core.query_quantile(q)
    }

    fn count(&self) -> u64 {
        self.core.count + self.head.len() as u64
    }

    fn summary_len(&self) -> usize {
        self.core.samples.len()
    }

    fn epsilon(&self) -> f64 {
        self.core.epsilon
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;
    use crate::sketch::assert_rank_error_bounded;

    fn feed(eps: f64, head: usize, data: &[Key]) -> SparkGk {
        let mut sk = SparkGk::with_params(eps, head, DEFAULT_COMPRESS_THRESHOLD);
        for &v in data {
            sk.insert(v);
        }
        sk.finalize();
        sk
    }

    #[test]
    fn buffer_flushes_at_capacity() {
        let mut sk = SparkGk::with_params(0.01, 100, 50);
        for v in 0..99 {
            sk.insert(v);
        }
        assert_eq!(sk.buffered(), 99);
        sk.insert(99);
        assert_eq!(sk.buffered(), 0, "capacity hit must flush");
        assert_eq!(sk.count(), 100);
    }

    #[test]
    fn random_stream_error_bounded() {
        let mut rng = SplitMix64::new(8);
        let data: Vec<Key> = (0..60_000)
            .map(|_| (rng.next_u64() % 2_000_000_000) as i64 as Key - 1_000_000_000)
            .collect();
        let sk = feed(0.01, 5_000, &data);
        assert_rank_error_bounded(sk.core(), data, 0.01, "spark rand");
    }

    #[test]
    fn partial_buffer_finalize() {
        let data: Vec<Key> = (0..1234).collect();
        let sk = feed(0.01, 50_000, &data); // never hits capacity
        assert_eq!(sk.count(), 1234);
        assert_rank_error_bounded(sk.core(), data, 0.01, "spark partial");
    }

    #[test]
    fn default_params_match_spark() {
        let sk = SparkGk::new(0.01);
        assert_eq!(sk.head_capacity, 50_000);
        assert_eq!(sk.compress_threshold, 10_000);
    }

    #[test]
    fn sorted_input_error_bounded() {
        let data: Vec<Key> = (0..50_000).collect();
        let sk = feed(0.02, 10_000, &data);
        assert_rank_error_bounded(sk.core(), data, 0.02, "spark sorted");
    }

    #[test]
    fn merge_flushes_both_sides() {
        let mut a = SparkGk::with_params(0.02, 1_000, 500);
        let mut b = SparkGk::with_params(0.02, 1_000, 500);
        for v in 0..600 {
            a.insert(v);
        }
        for v in 600..1200 {
            b.insert(v);
        }
        let m = a.merge(b);
        assert_eq!(m.count(), 1200);
    }

    #[test]
    fn can_exceed_space_bound_between_compresses() {
        // the paper notes Spark GK temporarily exceeds the memory bound;
        // compressThreshold is what restores it
        let mut sk = SparkGk::with_params(0.1, 1_000, 10_000);
        for v in 0..5_000 {
            sk.insert(v);
        }
        sk.finalize();
        assert!(sk.core().invariant_holds());
    }
}
