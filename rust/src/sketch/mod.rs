//! Greenwald–Khanna quantile sketches (§IV-D/E): the approximate substrate
//! GK Select's pivot comes from.
//!
//! Three variants, exactly as the paper dissects them:
//!
//! * [`classical::ClassicalGk`] — per-insert binary-search insert with a
//!   compress every `⌈1/(2ε)⌉` insertions (Greenwald & Khanna 2001).
//! * [`spark::SparkGk`] — Spark 3.5.5's `QuantileSummaries`: a head
//!   buffer of `B = 50 000` appended to in `O(1)`, flushed (sort + linear
//!   merge) when full, compressed past `compressThreshold = 10 000`.
//! * [`modified::ModifiedGk`] — the paper's mSGK: the head buffer starts
//!   small and is re-sized to `⌈α·|S|⌉` after every flush+compress,
//!   recovering the classical `O(log 1/ε + log log εn)` amortized insert.
//!
//! All variants share [`GkCore`]: the ordered `(vᵢ, gᵢ, Δᵢ)` summary, the
//! invariant `gᵢ + Δᵢ ≤ ⌊2εn⌋` (paper Eq. 1), the compress pass, the rank
//! query, and the Spark-style pairwise merge used by the driver.

pub mod classical;
pub mod kll;
pub mod modified;
pub mod spark;

use crate::cluster::netmodel::{NetSize, CONTAINER_OVERHEAD};
use crate::Key;

/// One summary tuple `(vᵢ, gᵢ, Δᵢ)` (paper Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GkTuple {
    /// Sample value, strictly increasing across the summary.
    pub v: Key,
    /// Gap: lower bound on the number of values in `(v_{i-1}, v_i]`.
    pub g: u64,
    /// Slack: how far above its minimum rank `v_i`'s true rank may sit.
    pub delta: u64,
}

/// Common interface over the sketch variants (what `approxQuantile` and
/// GK Select's round 1 program against).
pub trait QuantileSketch: Sized {
    /// Stream one value in.
    fn insert(&mut self, v: Key);

    /// Flush any buffered values so queries see everything inserted.
    fn finalize(&mut self);

    /// Merge two finalized sketches (driver-side; Spark-style delta
    /// adjustment).
    fn merge(self, other: Self) -> Self;

    /// Approximate value at quantile `q` (requires `finalize`).
    fn query(&self, q: f64) -> Option<Key>;

    /// Number of values inserted.
    fn count(&self) -> u64;

    /// Number of summary tuples currently held.
    fn summary_len(&self) -> usize;

    /// The ε this sketch was built with.
    fn epsilon(&self) -> f64;
}

/// Shared summary state + the paper's core operations.
#[derive(Debug, Clone)]
pub struct GkCore {
    pub samples: Vec<GkTuple>,
    pub count: u64,
    pub epsilon: f64,
}

impl GkCore {
    /// Build a summary directly from **sorted** data: one sample every
    /// `⌊2εn⌋` ranks with exact gaps and zero slack (the paper's §IV-D
    /// "if we have all the data ahead of time" construction). `O(n + S)`
    /// after the sort, invariant holds by construction — the fast path
    /// when the executor owns the whole partition (§Perf L3.4).
    pub fn from_sorted(sorted: &[Key], epsilon: f64) -> Self {
        debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input not sorted");
        let mut core = GkCore::new(epsilon);
        let n = sorted.len();
        if n == 0 {
            return core;
        }
        core.count = n as u64;
        let step = ((2.0 * epsilon * n as f64).floor() as usize).max(1);
        let mut samples = Vec::with_capacity(n / step + 2);
        samples.push(GkTuple {
            v: sorted[0],
            g: 1,
            delta: 0,
        });
        let mut prev = 0usize;
        let mut i = step;
        while i < n - 1 {
            samples.push(GkTuple {
                v: sorted[i],
                g: (i - prev) as u64,
                delta: 0,
            });
            prev = i;
            i += step;
        }
        if n > 1 {
            samples.push(GkTuple {
                v: sorted[n - 1],
                g: (n - 1 - prev) as u64,
                delta: 0,
            });
        }
        core.samples = samples;
        core
    }

    pub fn new(epsilon: f64) -> Self {
        assert!(
            epsilon > 0.0 && epsilon < 1.0,
            "epsilon must be in (0,1), got {epsilon}"
        );
        Self {
            samples: Vec::new(),
            count: 0,
            epsilon,
        }
    }

    /// `⌊2εn⌋` — the invariant's right-hand side at the current count.
    pub fn threshold(&self) -> u64 {
        (2.0 * self.epsilon * self.count as f64).floor() as u64
    }

    /// Paper Eq. 1: every tuple satisfies `g + Δ ≤ ⌊2εn⌋` (we allow the
    /// two extremes their defining exception of g=1, Δ=0 at tiny n).
    pub fn invariant_holds(&self) -> bool {
        let t = self.threshold().max(1);
        self.samples.iter().all(|s| s.g + s.delta <= t)
    }

    /// Greedy right-to-left compress: merge tuple `i` into `i+1` while the
    /// combined gap and slack still satisfy the invariant. `O(|S|)`.
    pub fn compress(&mut self) {
        if self.samples.len() <= 2 {
            return;
        }
        let t = self.threshold();
        let mut out: Vec<GkTuple> = Vec::with_capacity(self.samples.len());
        // keep both extremes untouched (they pin the exact min/max); walk
        // the interior right-to-left accumulating into successors
        let mut iter = self.samples[1..].iter().rev();
        let mut head = *iter.next().expect("nonempty");
        out.push(head);
        for &s in iter {
            if s.g + head.g + head.delta <= t {
                // merge s into its successor (drop s, grow successor gap)
                head.g += s.g;
                *out.last_mut().expect("nonempty") = head;
            } else {
                out.push(s);
                head = s;
            }
        }
        out.push(self.samples[0]);
        out.reverse();
        self.samples = out;
    }

    /// Merge a *sorted* batch of raw values into the summary in one linear
    /// pass (Spark's `insertHeadBuffer`): each inserted value gets `g = 1`
    /// and `Δ = ⌊2εn⌋ - 1` (0 at the extremes).
    pub fn merge_sorted_batch(&mut self, batch: &[Key]) {
        if batch.is_empty() {
            return;
        }
        debug_assert!(batch.windows(2).all(|w| w[0] <= w[1]), "batch not sorted");
        let mut out: Vec<GkTuple> =
            Vec::with_capacity(self.samples.len() + batch.len());
        let mut si = 0usize;
        for (bi, &v) in batch.iter().enumerate() {
            while si < self.samples.len() && self.samples[si].v <= v {
                out.push(self.samples[si]);
                si += 1;
            }
            self.count += 1;
            let at_edge = out.is_empty() || (si == self.samples.len() && bi == batch.len() - 1);
            let delta = if at_edge {
                0
            } else {
                self.threshold().saturating_sub(1)
            };
            out.push(GkTuple { v, g: 1, delta });
        }
        out.extend_from_slice(&self.samples[si..]);
        self.samples = out;
    }

    /// Rank query (Spark's `query` semantics): the first sample whose
    /// rank bounds sit within `targetError = εn` of `rank` (1-based).
    /// GK's guarantee says one exists while the invariant holds; after
    /// lossy merges we fall back to the sample whose bound interval is
    /// closest to the target.
    pub fn query_rank(&self, rank: u64) -> Option<Key> {
        if self.samples.is_empty() {
            return None;
        }
        let target_error = self.epsilon * self.count as f64;
        let rank_f = rank as f64;
        let mut min_rank = 0u64;
        let mut best: Option<(f64, Key)> = None;
        for s in &self.samples {
            min_rank += s.g;
            let max_rank = (min_rank + s.delta) as f64;
            if max_rank - target_error <= rank_f && rank_f <= min_rank as f64 + target_error {
                return Some(s.v);
            }
            // distance of rank to the sample's bound interval
            let dist = if rank_f < min_rank as f64 {
                min_rank as f64 - rank_f
            } else if rank_f > max_rank {
                rank_f - max_rank
            } else {
                0.0
            };
            if best.map(|(d, _)| dist < d).unwrap_or(true) {
                best = Some((dist, s.v));
            }
        }
        best.map(|(_, v)| v)
    }

    /// Guaranteed value band around 1-based `rank`: a pair `(lo, hi)`
    /// with `lo ≤ x₍rank₎ ≤ hi`, derived from the summary's rank
    /// intervals alone (no ε slop on top).
    ///
    /// `lo` is the largest sample whose **maximum** possible rank is
    /// still ≤ `rank` — its true rank r satisfies `r ≤ rank`, hence
    /// `v = x₍r₎ ≤ x₍rank₎`. Symmetrically `hi` is the smallest sample
    /// whose **minimum** possible rank is ≥ `rank`. The first/last
    /// samples pin the exact min/max, so the fallbacks are always valid.
    /// By the invariant (Eq. 1) the band spans O(εn) ranks, which is what
    /// lets GK Select's fused scan extract every candidate in one pass
    /// with bounded traffic.
    pub fn query_rank_bounds(&self, rank: u64) -> Option<(Key, Key)> {
        if self.samples.is_empty() || self.count == 0 {
            return None;
        }
        let rank = rank.clamp(1, self.count);
        // unconditional fallbacks: global min (rank 1) and max (rank n)
        let mut lo = self.samples[0].v;
        let mut hi = self.samples[self.samples.len() - 1].v;
        let mut min_rank = 0u64;
        for s in &self.samples {
            min_rank += s.g;
            let max_rank = min_rank + s.delta;
            if max_rank <= rank {
                lo = s.v; // samples ascend: the last hit is the largest
            }
            if min_rank >= rank {
                hi = s.v; // first hit is the smallest such sample
                break;
            }
        }
        Some((lo, hi))
    }

    /// Value at quantile `q` (Spark convention: rank = ⌈q·n⌉ clamped ≥1).
    pub fn query_quantile(&self, q: f64) -> Option<Key> {
        assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
        if self.count == 0 {
            return None;
        }
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        self.query_rank(rank)
    }

    /// Spark-style merge of two compressed summaries: merge-sort the
    /// sample lists; a sample strictly inside the other summary's value
    /// range picks up the other's `⌊2εn⌋` as extra slack.
    pub fn merge_with(mut self, mut other: GkCore) -> GkCore {
        if other.count == 0 {
            return self;
        }
        if self.count == 0 {
            return other;
        }
        let eps = self.epsilon.max(other.epsilon);
        let add_to_self = (2.0 * other.epsilon * other.count as f64).floor() as u64;
        let add_to_other = (2.0 * self.epsilon * self.count as f64).floor() as u64;

        let (a, b) = (&mut self.samples, &mut other.samples);
        let mut merged: Vec<GkTuple> = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() || j < b.len() {
            let take_a = j >= b.len() || (i < a.len() && a[i].v <= b[j].v);
            if take_a {
                let mut s = a[i];
                // strictly inside other's range?
                if j > 0 && j < b.len() {
                    s.delta += add_to_self;
                }
                merged.push(s);
                i += 1;
            } else {
                let mut s = b[j];
                if i > 0 && i < a.len() {
                    s.delta += add_to_other;
                }
                merged.push(s);
                j += 1;
            }
        }

        let mut out = GkCore {
            samples: merged,
            count: self.count + other.count,
            epsilon: eps,
        };
        out.compress();
        out
    }
}

impl NetSize for GkCore {
    fn net_bytes(&self) -> u64 {
        // (v, g, delta) serialized per tuple + count/epsilon header
        CONTAINER_OVERHEAD + 16 + self.samples.len() as u64 * (4 + 8 + 8)
    }
}

/// Exhaustive oracle check used by tests: every query across the quantile
/// range lands within `slack · n` ranks of the true rank.
#[cfg(test)]
pub(crate) fn assert_rank_error_bounded(
    core: &GkCore,
    mut data: Vec<Key>,
    slack: f64,
    label: &str,
) {
    data.sort_unstable();
    let n = data.len() as f64;
    for pct in 1..=99 {
        let q = pct as f64 / 100.0;
        let got = core.query_quantile(q).expect("nonempty sketch");
        // true rank range of `got` in data (1-based)
        let lo = data.partition_point(|&x| x < got) as f64 + 1.0;
        let hi = data.partition_point(|&x| x <= got) as f64;
        let target = (q * n).ceil().max(1.0);
        let err = if target < lo {
            lo - target
        } else if target > hi {
            target - hi
        } else {
            0.0
        };
        assert!(
            err <= (slack * n).ceil() + 1.0,
            "{label}: rank error {err} > {} at q={q} (n={n}, got={got})",
            (slack * n).ceil() + 1.0,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(core: &mut GkCore, values: &[Key]) {
        // classical-style: batch of one
        for &v in values {
            core.merge_sorted_batch(&[v]);
        }
    }

    #[test]
    fn empty_core_queries_none() {
        let core = GkCore::new(0.01);
        assert_eq!(core.query_quantile(0.5), None);
        assert!(core.invariant_holds());
    }

    #[test]
    fn gaps_sum_to_count() {
        let mut core = GkCore::new(0.1);
        stream(&mut core, &(0..1000).collect::<Vec<_>>());
        let total_g: u64 = core.samples.iter().map(|s| s.g).sum();
        assert_eq!(total_g, 1000);
        core.compress();
        let total_g: u64 = core.samples.iter().map(|s| s.g).sum();
        assert_eq!(total_g, 1000, "compress must preserve total gap mass");
    }

    #[test]
    fn compress_shrinks_and_keeps_invariant() {
        let mut core = GkCore::new(0.05);
        stream(&mut core, &(0..5000).rev().collect::<Vec<_>>());
        let before = core.samples.len();
        core.compress();
        assert!(core.samples.len() < before);
        assert!(core.invariant_holds());
    }

    #[test]
    fn merge_sorted_batch_bulk() {
        let mut core = GkCore::new(0.01);
        let batch: Vec<Key> = (0..10_000).collect();
        core.merge_sorted_batch(&batch);
        assert_eq!(core.count, 10_000);
        assert!(core.samples.windows(2).all(|w| w[0].v <= w[1].v));
    }

    #[test]
    fn query_exact_on_small_stream() {
        let mut core = GkCore::new(0.001);
        stream(&mut core, &(1..=100).collect::<Vec<_>>());
        core.compress();
        // with epsilon tiny, the sketch is near-exact on 100 points
        let med = core.query_quantile(0.5).unwrap();
        assert!((49..=51).contains(&med), "median {med} out of band");
    }

    #[test]
    fn rank_error_bounded_uniform() {
        let mut core = GkCore::new(0.05);
        let mut rng = crate::select::SplitMix64::new(4);
        let data: Vec<Key> = (0..20_000)
            .map(|_| (rng.next_u64() % 2_000_000) as i64 as Key)
            .collect();
        let mut sorted = data.clone();
        sorted.sort_unstable();
        for chunk in data.chunks(1000) {
            let mut b = chunk.to_vec();
            b.sort_unstable();
            core.merge_sorted_batch(&b);
            core.compress();
        }
        assert_rank_error_bounded(&core, data, 0.05, "uniform stream");
    }

    #[test]
    fn merge_two_cores_preserves_count_and_order() {
        let mut a = GkCore::new(0.02);
        let mut b = GkCore::new(0.02);
        a.merge_sorted_batch(&(0..5000).collect::<Vec<_>>());
        a.compress();
        b.merge_sorted_batch(&(5000..10_000).collect::<Vec<_>>());
        b.compress();
        let m = a.merge_with(b);
        assert_eq!(m.count, 10_000);
        assert!(m.samples.windows(2).all(|w| w[0].v <= w[1].v));
    }

    #[test]
    fn merged_rank_error_bounded() {
        let mut rng = crate::select::SplitMix64::new(9);
        let data: Vec<Key> = (0..40_000)
            .map(|_| (rng.next_u64() % 1_000_000) as i64 as Key)
            .collect();
        let mut cores: Vec<GkCore> = data
            .chunks(10_000)
            .map(|chunk| {
                let mut c = GkCore::new(0.02);
                let mut b = chunk.to_vec();
                b.sort_unstable();
                c.merge_sorted_batch(&b);
                c.compress();
                c
            })
            .collect();
        let mut merged = cores.remove(0);
        for c in cores {
            merged = merged.merge_with(c);
        }
        assert_eq!(merged.count, 40_000);
        // pairwise merge can accumulate slack; allow 2x epsilon
        assert_rank_error_bounded(&merged, data, 0.04, "merged");
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = GkCore::new(0.01);
        a.merge_sorted_batch(&[1, 2, 3]);
        let b = GkCore::new(0.01);
        let m = a.clone().merge_with(b);
        assert_eq!(m.count, 3);
        let b = GkCore::new(0.01);
        let m2 = b.merge_with(a);
        assert_eq!(m2.count, 3);
    }

    #[test]
    fn net_bytes_tracks_summary_len() {
        let mut a = GkCore::new(0.01);
        a.merge_sorted_batch(&(0..100).collect::<Vec<_>>());
        assert_eq!(a.net_bytes(), CONTAINER_OVERHEAD + 16 + 100 * 20);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_epsilon() {
        GkCore::new(0.0);
    }

    #[test]
    fn from_sorted_invariant_and_error() {
        let mut rng = crate::select::SplitMix64::new(21);
        let mut data: Vec<Key> = (0..50_000)
            .map(|_| (rng.next_u64() % 3_000_000) as Key)
            .collect();
        let mut sorted = data.clone();
        sorted.sort_unstable();
        let core = GkCore::from_sorted(&sorted, 0.01);
        assert_eq!(core.count, 50_000);
        assert!(core.invariant_holds());
        assert!(core.samples.windows(2).all(|w| w[0].v <= w[1].v));
        let total_g: u64 = core.samples.iter().map(|s| s.g).sum();
        assert_eq!(total_g, 50_000);
        data.sort_unstable();
        assert_rank_error_bounded(&core, data, 0.01, "from_sorted");
    }

    #[test]
    fn from_sorted_edge_sizes() {
        assert_eq!(GkCore::from_sorted(&[], 0.1).count, 0);
        let c = GkCore::from_sorted(&[7], 0.1);
        assert_eq!(c.count, 1);
        assert_eq!(c.query_quantile(0.5), Some(7));
        let c = GkCore::from_sorted(&[1, 2], 0.1);
        assert_eq!(c.query_quantile(0.0), Some(1));
        assert_eq!(c.query_quantile(1.0), Some(2));
        // extremes are pinned exactly
        let c = GkCore::from_sorted(&(0..10_000).collect::<Vec<_>>(), 0.05);
        assert_eq!(c.samples.first().unwrap().v, 0);
        assert_eq!(c.samples.last().unwrap().v, 9_999);
    }

    #[test]
    fn rank_bounds_bracket_true_value() {
        let mut rng = crate::select::SplitMix64::new(77);
        let mut data: Vec<Key> = (0..30_000)
            .map(|_| (rng.next_u64() % 4_000_000) as i64 as Key)
            .collect();
        data.sort_unstable();
        let eps = 0.02;
        let core = GkCore::from_sorted(&data, eps);
        let n = data.len() as u64;
        for pct in [1u64, 10, 25, 50, 75, 90, 99] {
            let rank = (pct * n / 100).max(1);
            let truth = data[(rank - 1) as usize];
            let (lo, hi) = core.query_rank_bounds(rank).unwrap();
            assert!(lo <= truth && truth <= hi, "rank {rank}: [{lo},{hi}] ∌ {truth}");
            // band stays O(εn) ranks wide (from_sorted: ≤ 2·⌊2εn⌋ + 2)
            let lo_rank = data.partition_point(|&x| x < lo) as u64;
            let hi_rank = data.partition_point(|&x| x <= hi) as u64;
            let width = hi_rank - lo_rank;
            let bound = 2 * (2.0 * eps * n as f64).floor() as u64 + 2;
            assert!(width <= bound, "rank {rank}: band width {width} > {bound}");
        }
    }

    #[test]
    fn rank_bounds_bracket_after_merge() {
        let mut rng = crate::select::SplitMix64::new(78);
        let data: Vec<Key> = (0..40_000)
            .map(|_| (rng.next_u64() % 1_000_000) as Key)
            .collect();
        let mut merged: Option<GkCore> = None;
        for chunk in data.chunks(5_000) {
            let mut b = chunk.to_vec();
            b.sort_unstable();
            let c = GkCore::from_sorted(&b, 0.01);
            merged = Some(match merged {
                None => c,
                Some(m) => m.merge_with(c),
            });
        }
        let core = merged.unwrap();
        let mut sorted = data;
        sorted.sort_unstable();
        for rank in [1u64, 400, 20_000, 39_999, 40_000] {
            let truth = sorted[(rank - 1) as usize];
            let (lo, hi) = core.query_rank_bounds(rank).unwrap();
            assert!(lo <= truth && truth <= hi, "rank {rank}: [{lo},{hi}] ∌ {truth}");
        }
    }

    #[test]
    fn rank_bounds_edges() {
        assert_eq!(GkCore::new(0.1).query_rank_bounds(1), None);
        let c = GkCore::from_sorted(&[7], 0.1);
        assert_eq!(c.query_rank_bounds(1), Some((7, 7)));
        let c = GkCore::from_sorted(&(0..100).collect::<Vec<_>>(), 0.05);
        // out-of-range ranks clamp to the extremes
        assert_eq!(c.query_rank_bounds(0).unwrap().0, 0);
        assert_eq!(c.query_rank_bounds(10_000).unwrap().1, 99);
        let (lo, hi) = c.query_rank_bounds(1).unwrap();
        assert_eq!(lo, 0);
        assert!(hi >= 0);
        let (_, hi) = c.query_rank_bounds(100).unwrap();
        assert_eq!(hi, 99);
    }

    #[test]
    fn from_sorted_merges_like_streamed() {
        let a = GkCore::from_sorted(&(0..5_000).collect::<Vec<_>>(), 0.02);
        let b = GkCore::from_sorted(&(5_000..10_000).collect::<Vec<_>>(), 0.02);
        let m = a.merge_with(b);
        assert_eq!(m.count, 10_000);
        let med = m.query_quantile(0.5).unwrap();
        assert!((4_700..=5_300).contains(&med), "merged median {med}");
    }
}
