//! Classical GK Sketch (Greenwald & Khanna 2001) — per-insert variant.
//!
//! Every arriving value is placed by binary search and inserted with
//! `g = 1`, `Δ = g_succ + Δ_succ − 1` (0 at the extremes); every
//! `⌈1/(2ε)⌉` insertions the summary is compressed. Space stays
//! `Θ((1/ε)·log(εn))` (paper Eq. 2).
//!
//! The ordered collection is a `Vec` (memmove insert) rather than the
//! balanced tree the paper mentions: summaries are small (thousands of
//! tuples) and the contiguous layout wins on real hardware; asymptotics
//! of the *executor pass* are unchanged because the compress schedule
//! dominates.

use super::{GkCore, GkTuple, QuantileSketch};
use crate::Key;

/// Per-insert Greenwald–Khanna summary.
#[derive(Debug, Clone)]
pub struct ClassicalGk {
    core: GkCore,
    inserts_since_compress: u64,
    compress_every: u64,
}

impl ClassicalGk {
    pub fn new(epsilon: f64) -> Self {
        let compress_every = (1.0 / (2.0 * epsilon)).ceil() as u64;
        Self {
            core: GkCore::new(epsilon),
            inserts_since_compress: 0,
            compress_every: compress_every.max(1),
        }
    }

    /// Expose the underlying summary (driver-side merge, tests).
    pub fn core(&self) -> &GkCore {
        &self.core
    }

    pub fn into_core(self) -> GkCore {
        self.core
    }

    pub fn from_core(core: GkCore) -> Self {
        let compress_every = (1.0 / (2.0 * core.epsilon)).ceil() as u64;
        Self {
            core,
            inserts_since_compress: 0,
            compress_every: compress_every.max(1),
        }
    }
}

impl QuantileSketch for ClassicalGk {
    fn insert(&mut self, v: Key) {
        let samples = &mut self.core.samples;
        // binary search for the first sample with value >= v
        let pos = samples.partition_point(|s| s.v < v);
        let delta = if pos == 0 || pos == samples.len() {
            0
        } else {
            let succ = samples[pos];
            (succ.g + succ.delta).saturating_sub(1)
        };
        samples.insert(pos, GkTuple { v, g: 1, delta });
        self.core.count += 1;
        self.inserts_since_compress += 1;
        if self.inserts_since_compress >= self.compress_every {
            self.core.compress();
            self.inserts_since_compress = 0;
        }
    }

    fn finalize(&mut self) {
        self.core.compress();
    }

    fn merge(self, other: Self) -> Self {
        Self::from_core(self.core.merge_with(other.core))
    }

    fn query(&self, q: f64) -> Option<Key> {
        self.core.query_quantile(q)
    }

    fn count(&self) -> u64 {
        self.core.count
    }

    fn summary_len(&self) -> usize {
        self.core.samples.len()
    }

    fn epsilon(&self) -> f64 {
        self.core.epsilon
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;
    use crate::sketch::assert_rank_error_bounded;

    fn feed(eps: f64, data: &[Key]) -> ClassicalGk {
        let mut sk = ClassicalGk::new(eps);
        for &v in data {
            sk.insert(v);
        }
        sk.finalize();
        sk
    }

    #[test]
    fn ascending_stream_error_bounded() {
        let data: Vec<Key> = (0..10_000).collect();
        let sk = feed(0.01, &data);
        assert!(sk.core().invariant_holds());
        assert_rank_error_bounded(sk.core(), data, 0.01, "classical asc");
    }

    #[test]
    fn descending_stream_error_bounded() {
        let data: Vec<Key> = (0..10_000).rev().collect();
        let sk = feed(0.01, &data);
        assert_rank_error_bounded(sk.core(), data, 0.01, "classical desc");
    }

    #[test]
    fn random_stream_error_bounded() {
        let mut rng = SplitMix64::new(5);
        let data: Vec<Key> = (0..30_000)
            .map(|_| (rng.next_u64() % 2_000_000_000) as i64 as Key - 1_000_000_000)
            .collect();
        let sk = feed(0.02, &data);
        assert_rank_error_bounded(sk.core(), data, 0.02, "classical rand");
    }

    #[test]
    fn space_stays_sublinear() {
        let mut rng = SplitMix64::new(6);
        let data: Vec<Key> = (0..100_000).map(|_| rng.next_u64() as Key).collect();
        let sk = feed(0.01, &data);
        // Θ((1/ε)·log(εn)) with ε=0.01, n=1e5 → ~100·10 = 1000 tuples;
        // generous factor for constants
        assert!(
            sk.summary_len() < 4_000,
            "summary ballooned to {}",
            sk.summary_len()
        );
    }

    #[test]
    fn duplicates_heavy() {
        let data: Vec<Key> = (0..20_000).map(|i| i % 5).collect();
        let sk = feed(0.01, &data);
        assert_rank_error_bounded(sk.core(), data, 0.01, "classical dups");
    }

    #[test]
    fn count_tracks_inserts() {
        let sk = feed(0.1, &[5, 3, 1]);
        assert_eq!(sk.count(), 3);
        assert_eq!(sk.query(0.0), Some(1));
        assert_eq!(sk.query(1.0), Some(5));
    }

    #[test]
    fn merge_of_disjoint_ranges() {
        let a = feed(0.02, &(0..5_000).collect::<Vec<_>>());
        let b = feed(0.02, &(5_000..10_000).collect::<Vec<_>>());
        let m = a.merge(b);
        assert_eq!(m.count(), 10_000);
        let med = m.query(0.5).unwrap();
        assert!(
            (4_700..=5_300).contains(&med),
            "merged median {med} too far off"
        );
    }
}
