//! KLL sketch (Karnin, Lang & Liberty, FOCS'16) — the asymptotically
//! optimal mergeable quantile summary the paper surveys in §II-B1.
//!
//! Included as an alternative pivot source for the sketch-choice
//! ablation: `O((1/ε)·log log(1/ε))` space versus GK's
//! `O((1/ε)·log(εn))`, randomized additive-`εn` rank error versus GK's
//! deterministic bound. `benches/sketch_variants.rs` compares insert
//! throughput and realized pivot quality against the GK family.
//!
//! Standard multi-level compactor design: level `i` stores items of
//! weight `2^i`; a full level is sorted and every other element (random
//! offset) is promoted. Capacities decay geometrically (`c = 2/3`) from
//! `k` at the top level.

use super::QuantileSketch;
use crate::select::SplitMix64;
use crate::Key;

/// Default top-level capacity (DataSketches' default; ε ≈ 1.65/k at 99%
/// confidence → ~0.8% rank error).
pub const DEFAULT_K: usize = 200;

const DECAY: f64 = 2.0 / 3.0;
const MIN_LEVEL_CAP: usize = 8;

/// Multi-level compactor KLL sketch.
#[derive(Debug, Clone)]
pub struct KllSketch {
    /// `levels[i]` holds items of weight `2^i` (unsorted except after
    /// compaction).
    levels: Vec<Vec<Key>>,
    k: usize,
    count: u64,
    rng: SplitMix64,
}

impl KllSketch {
    pub fn new(seed: u64) -> Self {
        Self::with_k(DEFAULT_K, seed)
    }

    pub fn with_k(k: usize, seed: u64) -> Self {
        assert!(k >= 8, "k must be at least 8, got {k}");
        Self {
            levels: vec![Vec::new()],
            k,
            count: 0,
            rng: SplitMix64::new(seed),
        }
    }

    /// Capacity of level `i` when the sketch currently has `num` levels.
    fn level_capacity(&self, i: usize, num: usize) -> usize {
        let depth = (num - 1 - i) as i32;
        ((self.k as f64 * DECAY.powi(depth)).ceil() as usize).max(MIN_LEVEL_CAP)
    }

    fn total_capacity(&self) -> usize {
        (0..self.levels.len())
            .map(|i| self.level_capacity(i, self.levels.len()))
            .sum()
    }

    fn total_items(&self) -> usize {
        self.levels.iter().map(Vec::len).sum()
    }

    /// Compact the lowest over-full level: sort, keep every other item,
    /// promote the rest one level up (doubling their weight).
    fn compress(&mut self) {
        while self.total_items() > self.total_capacity() {
            let num = self.levels.len();
            let mut target = None;
            for i in 0..num {
                if self.levels[i].len() > self.level_capacity(i, num) {
                    target = Some(i);
                    break;
                }
            }
            // everything within per-level caps but total over: compact
            // the largest level
            let i = target.unwrap_or_else(|| {
                (0..num)
                    .max_by_key(|&i| self.levels[i].len())
                    .expect("nonempty")
            });
            let mut level = std::mem::take(&mut self.levels[i]);
            if level.len() < 2 {
                self.levels[i] = level;
                return; // nothing to compact — capacity rules say stop
            }
            level.sort_unstable();
            let offset = (self.rng.next_u64() & 1) as usize;
            let promoted: Vec<Key> = level.iter().skip(offset).step_by(2).copied().collect();
            if i + 1 == self.levels.len() {
                self.levels.push(Vec::new());
            }
            self.levels[i + 1].extend_from_slice(&promoted);
        }
    }

    /// All (value, weight) pairs, sorted by value (query helper).
    fn weighted_items(&self) -> Vec<(Key, u64)> {
        let mut items: Vec<(Key, u64)> = Vec::with_capacity(self.total_items());
        for (i, level) in self.levels.iter().enumerate() {
            let w = 1u64 << i;
            items.extend(level.iter().map(|&v| (v, w)));
        }
        items.sort_unstable();
        items
    }

    /// Number of retained items (space check).
    pub fn retained(&self) -> usize {
        self.total_items()
    }
}

impl QuantileSketch for KllSketch {
    fn insert(&mut self, v: Key) {
        self.levels[0].push(v);
        self.count += 1;
        if self.total_items() > self.total_capacity() {
            self.compress();
        }
    }

    fn finalize(&mut self) {}

    fn merge(mut self, other: Self) -> Self {
        for (i, level) in other.levels.into_iter().enumerate() {
            if i >= self.levels.len() {
                self.levels.push(Vec::new());
            }
            self.levels[i].extend(level);
        }
        self.count += other.count;
        self.compress();
        self
    }

    fn query(&self, q: f64) -> Option<Key> {
        assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return None;
        }
        let items = self.weighted_items();
        let total: u64 = items.iter().map(|&(_, w)| w).sum();
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for &(v, w) in &items {
            acc += w;
            if acc >= target {
                return Some(v);
            }
        }
        items.last().map(|&(v, _)| v)
    }

    fn count(&self) -> u64 {
        self.count
    }

    fn summary_len(&self) -> usize {
        self.total_items()
    }

    fn epsilon(&self) -> f64 {
        1.65 / self.k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;

    fn rank_error(data: &mut Vec<Key>, sk: &KllSketch, q: f64) -> f64 {
        data.sort_unstable();
        let got = sk.query(q).unwrap();
        let n = data.len() as f64;
        let lo = data.partition_point(|&x| x < got) as f64;
        let hi = data.partition_point(|&x| x <= got) as f64;
        let target = q * n;
        if target < lo {
            (lo - target) / n
        } else if target > hi {
            (target - hi) / n
        } else {
            0.0
        }
    }

    #[test]
    fn rank_error_bounded_random_stream() {
        let mut rng = SplitMix64::new(3);
        let mut data: Vec<Key> = (0..200_000).map(|_| rng.next_u64() as Key).collect();
        let mut sk = KllSketch::new(42);
        for &v in &data {
            sk.insert(v);
        }
        for q in [0.01, 0.1, 0.5, 0.9, 0.99] {
            let err = rank_error(&mut data, &sk, q);
            assert!(err < 0.03, "q={q}: rank error {err}");
        }
    }

    #[test]
    fn space_stays_sublinear() {
        let mut rng = SplitMix64::new(4);
        let mut sk = KllSketch::new(1);
        for _ in 0..1_000_000 {
            sk.insert(rng.next_u64() as Key);
        }
        assert_eq!(sk.count(), 1_000_000);
        // ~3k retained for k=200 regardless of n
        assert!(sk.retained() < 5_000, "retained {}", sk.retained());
    }

    #[test]
    fn sorted_and_reversed_streams() {
        for rev in [false, true] {
            let mut data: Vec<Key> = (0..100_000).collect();
            if rev {
                data.reverse();
            }
            let mut sk = KllSketch::new(9);
            for &v in &data {
                sk.insert(v);
            }
            for q in [0.25, 0.5, 0.75] {
                let err = rank_error(&mut data, &sk, q);
                assert!(err < 0.03, "rev={rev} q={q}: {err}");
            }
        }
    }

    #[test]
    fn merge_preserves_error() {
        let mut rng = SplitMix64::new(5);
        let mut all: Vec<Key> = Vec::new();
        let mut merged = KllSketch::new(11);
        for part in 0..8 {
            let mut sk = KllSketch::new(100 + part);
            for _ in 0..25_000 {
                let v = (rng.next_u64() % 5_000_000) as Key;
                sk.insert(v);
                all.push(v);
            }
            merged = merged.merge(sk);
        }
        assert_eq!(merged.count(), 200_000);
        for q in [0.1, 0.5, 0.9] {
            let err = rank_error(&mut all, &merged, q);
            assert!(err < 0.04, "merged q={q}: {err}");
        }
    }

    #[test]
    fn tiny_streams_exact() {
        let mut sk = KllSketch::new(7);
        for v in [5, 1, 9, 3] {
            sk.insert(v);
        }
        assert_eq!(sk.query(0.0), Some(1));
        assert_eq!(sk.query(1.0), Some(9));
        assert_eq!(sk.count(), 4);
        assert_eq!(KllSketch::new(1).query(0.5), None);
    }

    #[test]
    fn duplicates_heavy() {
        let mut data: Vec<Key> = (0..100_000).map(|i| i % 3).collect();
        let mut sk = KllSketch::new(13);
        for &v in &data {
            sk.insert(v);
        }
        let err = rank_error(&mut data, &sk, 0.5);
        assert!(err < 0.03, "dup median err {err}");
    }

    #[test]
    #[should_panic]
    fn rejects_tiny_k() {
        KllSketch::with_k(2, 0);
    }
}
