//! Modified Spark GK (mSGK, §IV-E3): the paper's analysis-only variant.
//!
//! Two changes to Spark's implementation:
//!
//! 1. the head buffer starts small and after every flush+compress is
//!    resized to `B ← ⌈α·|S|⌉` (`α > 1`), so buffer work tracks the
//!    summary's `Θ((1/ε)log εn)` footprint instead of a fixed 50 000 —
//!    recovering the classical per-insert bound
//!    `O(log 1/ε + log log εn)` (paper Eq. 14);
//! 2. the driver merges per-partition sketches with a recursive tree
//!    reduction instead of `foldLeft` (see [`tree_merge`]), improving the
//!    driver complexity from `Θ((P/ε)log εn)`-dominated sequential merging.

use super::{GkCore, QuantileSketch};
use crate::Key;

/// Default buffer growth factor (`α`). The paper's analysis only needs
/// `α > 1`; 16 measured fastest on this box (§Perf L3.3 sweep: 33.5 →
/// 23.6 ns/insert from α=2 to α=16).
pub const DEFAULT_ALPHA: f64 = 16.0;
/// Initial head capacity before the first flush sizes it to the summary.
pub const INITIAL_HEAD: usize = 64;

/// Adaptive-buffer GK summary (the paper's mSGK).
#[derive(Debug, Clone)]
pub struct ModifiedGk {
    core: GkCore,
    head: Vec<Key>,
    head_capacity: usize,
    alpha: f64,
}

impl ModifiedGk {
    pub fn new(epsilon: f64) -> Self {
        Self::with_alpha(epsilon, DEFAULT_ALPHA)
    }

    pub fn with_alpha(epsilon: f64, alpha: f64) -> Self {
        assert!(alpha > 1.0, "alpha must exceed 1, got {alpha}");
        Self {
            core: GkCore::new(epsilon),
            head: Vec::with_capacity(INITIAL_HEAD),
            head_capacity: INITIAL_HEAD,
            alpha,
        }
    }

    fn flush(&mut self) {
        if self.head.is_empty() {
            return;
        }
        // §Perf L3.3: radix for large adaptive buffers, comparison sort
        // below the cutoff (radix_sort_i32 picks internally)
        crate::sort::radix::radix_sort_i32(&mut self.head);
        self.core.merge_sorted_batch(&self.head);
        self.head.clear();
        self.core.compress();
        // B ← ⌈α·|S|⌉ — buffer tracks the summary size
        self.head_capacity = ((self.alpha * self.core.samples.len() as f64).ceil() as usize)
            .max(INITIAL_HEAD);
    }

    pub fn core(&self) -> &GkCore {
        &self.core
    }

    pub fn into_core(mut self) -> GkCore {
        self.flush();
        self.core
    }

    pub fn from_core(core: GkCore, alpha: f64) -> Self {
        let head_capacity =
            ((alpha * core.samples.len() as f64).ceil() as usize).max(INITIAL_HEAD);
        Self {
            core,
            head: Vec::new(),
            head_capacity,
            alpha,
        }
    }

    /// Current adaptive buffer capacity (observable for the benches).
    pub fn head_capacity(&self) -> usize {
        self.head_capacity
    }
}

impl QuantileSketch for ModifiedGk {
    fn insert(&mut self, v: Key) {
        self.head.push(v);
        if self.head.len() >= self.head_capacity {
            self.flush();
        }
    }

    fn finalize(&mut self) {
        self.flush();
    }

    fn merge(mut self, mut other: Self) -> Self {
        self.flush();
        other.flush();
        let alpha = self.alpha;
        Self::from_core(self.core.merge_with(other.core), alpha)
    }

    fn query(&self, q: f64) -> Option<Key> {
        debug_assert!(
            self.head.is_empty(),
            "query before finalize misses buffered values"
        );
        self.core.query_quantile(q)
    }

    fn count(&self) -> u64 {
        self.core.count + self.head.len() as u64
    }

    fn summary_len(&self) -> usize {
        self.core.samples.len()
    }

    fn epsilon(&self) -> f64 {
        self.core.epsilon
    }
}

/// Driver-side recursive tree reduction over per-partition summaries —
/// mSGK change #2. `O(log P)` merge depth instead of `foldLeft`'s `O(P)`
/// sequential chain over ever-growing accumulators.
pub fn tree_merge(mut cores: Vec<GkCore>) -> Option<GkCore> {
    if cores.is_empty() {
        return None;
    }
    while cores.len() > 1 {
        let mut next = Vec::with_capacity(cores.len().div_ceil(2));
        let mut it = cores.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(a.merge_with(b)),
                None => next.push(a),
            }
        }
        cores = next;
    }
    cores.pop()
}

/// Driver-side sequential fold — what Spark's `approxQuantile` actually
/// does (`foldLeft`), kept for the sketch-variant bench comparison.
pub fn fold_merge(cores: Vec<GkCore>) -> Option<GkCore> {
    cores.into_iter().reduce(GkCore::merge_with)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;
    use crate::sketch::assert_rank_error_bounded;

    fn feed(eps: f64, data: &[Key]) -> ModifiedGk {
        let mut sk = ModifiedGk::new(eps);
        for &v in data {
            sk.insert(v);
        }
        sk.finalize();
        sk
    }

    #[test]
    fn buffer_grows_with_summary() {
        let mut rng = SplitMix64::new(12);
        let mut sk = ModifiedGk::new(0.01);
        let start_cap = sk.head_capacity();
        for _ in 0..200_000 {
            sk.insert((rng.next_u64() % 1_000_000) as Key);
        }
        sk.finalize();
        assert!(
            sk.head_capacity() > start_cap,
            "buffer should have grown from {start_cap}"
        );
        // and track α·|S|
        let expected = (sk.alpha * sk.summary_len() as f64).ceil() as usize;
        assert_eq!(sk.head_capacity(), expected.max(INITIAL_HEAD));
    }

    #[test]
    fn random_stream_error_bounded() {
        let mut rng = SplitMix64::new(13);
        let data: Vec<Key> = (0..80_000)
            .map(|_| (rng.next_u64() % 2_000_000_000) as i64 as Key - 1_000_000_000)
            .collect();
        let sk = feed(0.01, &data);
        assert_rank_error_bounded(sk.core(), data, 0.01, "msgk rand");
    }

    #[test]
    fn space_matches_bound() {
        let mut rng = SplitMix64::new(14);
        let data: Vec<Key> = (0..200_000).map(|_| rng.next_u64() as Key).collect();
        let sk = feed(0.01, &data);
        // (1/ε)·log2(εn) = 100·log2(2000) ≈ 1100; allow constants
        assert!(
            sk.summary_len() < 5_000,
            "summary {} exceeds space bound regime",
            sk.summary_len()
        );
    }

    #[test]
    fn tree_merge_equals_fold_merge_counts() {
        let mut rng = SplitMix64::new(15);
        let cores: Vec<GkCore> = (0..8)
            .map(|_| {
                let data: Vec<Key> =
                    (0..10_000).map(|_| (rng.next_u64() % 1_000_000) as Key).collect();
                feed(0.02, &data).into_core()
            })
            .collect();
        let t = tree_merge(cores.clone()).unwrap();
        let f = fold_merge(cores).unwrap();
        assert_eq!(t.count, f.count);
        assert_eq!(t.count, 80_000);
    }

    #[test]
    fn tree_merge_empty_and_single() {
        assert!(tree_merge(vec![]).is_none());
        let one = feed(0.05, &[1, 2, 3]).into_core();
        assert_eq!(tree_merge(vec![one]).unwrap().count, 3);
    }

    #[test]
    fn tree_merged_error_bounded() {
        let mut rng = SplitMix64::new(16);
        let mut all: Vec<Key> = Vec::new();
        let cores: Vec<GkCore> = (0..16)
            .map(|_| {
                let data: Vec<Key> = (0..5_000)
                    .map(|_| (rng.next_u64() % 10_000_000) as Key)
                    .collect();
                all.extend_from_slice(&data);
                feed(0.01, &data).into_core()
            })
            .collect();
        let merged = tree_merge(cores).unwrap();
        // log2(16)=4 pairwise levels; allow accumulated slack
        assert_rank_error_bounded(&merged, all, 0.04, "tree merged");
    }

    #[test]
    #[should_panic]
    fn rejects_alpha_below_one() {
        ModifiedGk::with_alpha(0.01, 0.5);
    }
}
