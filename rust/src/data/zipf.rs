//! Zipf sampler via Walker's alias method: exact `P(k) ∝ k^{-s}` over
//! `k ∈ [1, n]`, `O(n)` build, `O(1)` per sample.
//!
//! The paper's robustness study uses `s = 2.5` — at that exponent the
//! head carries almost all mass (ζ(2.5) ≈ 1.341 ⇒ P(1) ≈ 0.75), so the
//! alias table is the fastest *and* the most obviously-correct
//! construction (no envelope math to get subtly wrong).

use super::pcg::Pcg64;

/// Alias-table Zipf sampler.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    /// Scaled acceptance probability per bucket (compare against u64).
    prob: Vec<u64>,
    /// Alias target per bucket (0-based rank).
    alias: Vec<u32>,
}

impl ZipfSampler {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1 && s > 1.0, "need n>=1, s>1 (got n={n}, s={s})");
        assert!(n <= u32::MAX as u64, "universe too large for alias table");
        let n = n as usize;

        // normalized weights
        let weights: Vec<f64> = (1..=n).map(|k| (k as f64).powf(-s)).collect();
        let total: f64 = weights.iter().sum();

        // Walker/Vose alias construction
        let mut prob = vec![0u64; n];
        let mut alias = vec![0u32; n];
        let scale = n as f64 / total;
        let mut scaled: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &p) in scaled.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s_i), Some(&l_i)) = (small.last(), large.last()) {
            small.pop();
            // bucket s_i keeps probability scaled[s_i], overflows to l_i
            prob[s_i as usize] = (scaled[s_i as usize].clamp(0.0, 1.0) * u64::MAX as f64) as u64;
            alias[s_i as usize] = l_i;
            scaled[l_i as usize] = (scaled[l_i as usize] + scaled[s_i as usize]) - 1.0;
            if scaled[l_i as usize] < 1.0 {
                large.pop();
                small.push(l_i);
            }
        }
        // remaining buckets are (numerically) full
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = u64::MAX;
            alias[i as usize] = i;
        }
        Self { prob, alias }
    }

    /// Draw one rank in `[1, n]`.
    pub fn sample(&mut self, rng: &mut Pcg64) -> u64 {
        let n = self.prob.len() as u64;
        let bucket = (rng.next_u64() % n) as usize;
        let coin = rng.next_u64();
        let idx = if coin <= self.prob[bucket] {
            bucket as u64
        } else {
            self.alias[bucket] as u64
        };
        idx + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_in_range() {
        let mut rng = Pcg64::new(1, 1);
        let mut z = ZipfSampler::new(1000, 2.5);
        for _ in 0..10_000 {
            let k = z.sample(&mut rng);
            assert!((1..=1000).contains(&k));
        }
    }

    #[test]
    fn frequency_ratio_tracks_power_law() {
        let mut rng = Pcg64::new(2, 9);
        let mut z = ZipfSampler::new(10_000, 2.5);
        let n = 400_000;
        let mut c1 = 0u64;
        let mut c2 = 0u64;
        for _ in 0..n {
            match z.sample(&mut rng) {
                1 => c1 += 1,
                2 => c2 += 1,
                _ => {}
            }
        }
        // P(1)/P(2) = 2^2.5 ≈ 5.66
        let ratio = c1 as f64 / c2.max(1) as f64;
        assert!(
            (4.5..7.0).contains(&ratio),
            "rank1/rank2 ratio {ratio:.2} far from 2^2.5≈5.66 (c1={c1}, c2={c2})"
        );
    }

    #[test]
    fn rank_one_dominates() {
        let mut rng = Pcg64::new(3, 4);
        let mut z = ZipfSampler::new(1 << 20, 2.5);
        let n = 50_000;
        let ones = (0..n).filter(|_| z.sample(&mut rng) == 1).count();
        // ζ(2.5)≈1.341 → P(1)≈0.745
        let frac = ones as f64 / n as f64;
        assert!(
            (0.72..0.78).contains(&frac),
            "rank-1 mass {frac:.3}, want ≈0.745"
        );
    }

    #[test]
    fn mass_is_conserved() {
        // every bucket either keeps or aliases: sampling never panics and
        // the empirical mean matches the analytic mean for a small n
        let mut rng = Pcg64::new(4, 2);
        let mut z = ZipfSampler::new(8, 1.5);
        let total: f64 = (1..=8).map(|k| (k as f64).powf(-1.5)).sum();
        let expected: f64 = (1..=8).map(|k| k as f64 * (k as f64).powf(-1.5) / total).sum();
        let n = 200_000;
        let mean = (0..n).map(|_| z.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!(
            (mean - expected).abs() < 0.05,
            "mean {mean:.3} vs expected {expected:.3}"
        );
    }

    #[test]
    fn tiny_universe() {
        let mut rng = Pcg64::new(4, 4);
        let mut z = ZipfSampler::new(1, 2.5);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 1);
        }
    }
}
