//! PCG-XSH-RR 64/32-based generator with 64-bit output, plus a Box–Muller
//! Gaussian tap. In-repo so runs are reproducible with zero external RNG
//! dependencies.

/// PCG with 128-bit state folded into two 64-bit LCG lanes (enough quality
/// for workload generation; not cryptographic).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    cached_gauss: Option<f64>,
}

const MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// `seed` selects the stream start; `stream` selects the increment
    /// (distinct streams are statistically independent).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
            cached_gauss: None,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed);
        rng.step();
        rng
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
    }

    /// One 32-bit PCG-XSH-RR output.
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.step();
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (caches the paired variate).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.cached_gauss.take() {
            return g;
        }
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.cached_gauss = Some(r * theta.sin());
            return r * theta.cos();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let mut a = Pcg64::new(1, 2);
        let mut b = Pcg64::new(1, 2);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_distinct() {
        let mut a = Pcg64::new(1, 2);
        let mut b = Pcg64::new(1, 3);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(5, 5);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_chi_square_ish() {
        let mut r = Pcg64::new(9, 1);
        let mut buckets = [0u32; 16];
        let n = 160_000;
        for _ in 0..n {
            buckets[(r.next_u64() % 16) as usize] += 1;
        }
        let expected = n as f64 / 16.0;
        for (i, &b) in buckets.iter().enumerate() {
            let dev = (b as f64 - expected).abs() / expected;
            assert!(dev < 0.05, "bucket {i} off by {dev:.3}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(11, 7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "gaussian var {var}");
    }
}
