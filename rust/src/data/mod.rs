//! Workload generators (§VI-B): the four input distributions the paper's
//! robustness study uses, all seeded and reproducible, generated
//! per-partition so datasets materialize in parallel-friendly shards.
//!
//! * **Uniform** — i.i.d. from `[-1e9, 1e9)`; the Fig. 1/2 baseline.
//! * **Zipf** — exponent `s = 2.5` over a ranked universe mapped into the
//!   value range; models power-law data.
//! * **Bimodal** — 50/50 mix of two Gaussians at `±3.33e8`, σ `= 1.66e8`,
//!   clamped to the range.
//! * **Sorted** — partition `i` holds a non-overlapping contiguous band,
//!   locally sorted: globally ordered data, the pathological case for
//!   sampling-based splitters.

pub mod pcg;
pub mod zipf;

use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::{Key, KEY_HI, KEY_LO};
use pcg::Pcg64;

/// The paper's four input distributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Distribution {
    Uniform,
    Zipf,
    Bimodal,
    Sorted,
}

impl std::str::FromStr for Distribution {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "uniform" => Ok(Self::Uniform),
            "zipf" => Ok(Self::Zipf),
            "bimodal" => Ok(Self::Bimodal),
            "sorted" => Ok(Self::Sorted),
            other => anyhow::bail!("unknown distribution '{other}' (uniform|zipf|bimodal|sorted)"),
        }
    }
}

impl Distribution {
    pub fn generator(self, seed: u64) -> Box<dyn DataGenerator> {
        match self {
            Distribution::Uniform => Box::new(UniformGen::new(seed)),
            Distribution::Zipf => Box::new(ZipfGen::new(seed, 2.5)),
            Distribution::Bimodal => Box::new(BimodalGen::new(seed)),
            Distribution::Sorted => Box::new(SortedBandsGen::new(seed)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Zipf => "zipf",
            Distribution::Bimodal => "bimodal",
            Distribution::Sorted => "sorted",
        }
    }
}

/// A seeded distributed data source.
pub trait DataGenerator {
    /// Fill partition `p` of `num_partitions` with `len` keys.
    fn fill_partition(&self, p: usize, num_partitions: usize, len: usize, out: &mut Vec<Key>);

    /// Materialize `n` keys across the cluster's partitions.
    fn generate(&self, cluster: &mut Cluster, n: u64) -> Dataset<Key> {
        let p = cluster.cfg.partitions;
        let base = (n / p as u64) as usize;
        let extra = (n % p as u64) as usize;
        let parts: Vec<Vec<Key>> = (0..p)
            .map(|i| {
                let len = base + usize::from(i < extra);
                let mut v = Vec::with_capacity(len);
                self.fill_partition(i, p, len, &mut v);
                v
            })
            .collect();
        Dataset::from_partitions(parts).expect("cluster has at least one partition")
    }
}

fn partition_rng(seed: u64, p: usize) -> Pcg64 {
    // independent stream per partition: same dataset regardless of P order
    Pcg64::new(seed, 0x5851_F42D_4C95_7F2D ^ (p as u64))
}

/// Uniform over `[-1e9, 1e9)`.
#[derive(Debug, Clone)]
pub struct UniformGen {
    seed: u64,
}

impl UniformGen {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl DataGenerator for UniformGen {
    fn fill_partition(&self, p: usize, _np: usize, len: usize, out: &mut Vec<Key>) {
        let mut rng = partition_rng(self.seed, p);
        let span = (KEY_HI - KEY_LO) as u64;
        out.extend((0..len).map(|_| (KEY_LO + (rng.next_u64() % span) as i64) as Key));
    }
}

/// Zipf(s) over a ranked universe, ranks mapped into the value range.
#[derive(Debug, Clone)]
pub struct ZipfGen {
    seed: u64,
    exponent: f64,
    universe: u64,
}

impl ZipfGen {
    pub fn new(seed: u64, exponent: f64) -> Self {
        Self {
            seed,
            exponent,
            universe: 1 << 20,
        }
    }
}

impl DataGenerator for ZipfGen {
    fn fill_partition(&self, p: usize, _np: usize, len: usize, out: &mut Vec<Key>) {
        let mut rng = partition_rng(self.seed, p);
        let mut z = zipf::ZipfSampler::new(self.universe, self.exponent);
        let span = (KEY_HI - KEY_LO) as u64;
        let stride = (span / self.universe).max(1);
        out.extend((0..len).map(|_| {
            let rank = z.sample(&mut rng); // 1-based, heavily skewed to small ranks
            // spread ranks over the value range so heavy hitters are
            // specific values, like word-frequency data mapped to ids
            let mixed = rank.wrapping_mul(0x9E37_79B9_7F4A_7C15) % self.universe;
            (KEY_LO + (mixed * stride) as i64) as Key
        }));
    }
}

/// 50/50 mix of `N(-3.33e8, 1.66e8)` and `N(+3.33e8, 1.66e8)`, clamped.
#[derive(Debug, Clone)]
pub struct BimodalGen {
    seed: u64,
}

impl BimodalGen {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl DataGenerator for BimodalGen {
    fn fill_partition(&self, p: usize, _np: usize, len: usize, out: &mut Vec<Key>) {
        let mut rng = partition_rng(self.seed, p);
        const MU: f64 = 3.33e8;
        const SIGMA: f64 = 1.66e8;
        out.extend((0..len).map(|_| {
            let mu = if rng.next_u64() & 1 == 0 { -MU } else { MU };
            let v = mu + SIGMA * rng.next_gaussian();
            v.clamp(KEY_LO as f64, (KEY_HI - 1) as f64) as Key
        }));
    }
}

/// Globally sorted: partition `i` draws uniformly from its own contiguous
/// band of the range and sorts locally.
#[derive(Debug, Clone)]
pub struct SortedBandsGen {
    seed: u64,
}

impl SortedBandsGen {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl DataGenerator for SortedBandsGen {
    fn fill_partition(&self, p: usize, np: usize, len: usize, out: &mut Vec<Key>) {
        let mut rng = partition_rng(self.seed, p);
        let span = (KEY_HI - KEY_LO) as u64 / np as u64;
        let lo = KEY_LO + (p as u64 * span) as i64;
        out.extend((0..len).map(|_| (lo + (rng.next_u64() % span.max(1)) as i64) as Key));
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn gen_n(d: Distribution, n: u64) -> Dataset<Key> {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        d.generator(7).generate(&mut c, n)
    }

    #[test]
    fn uniform_covers_range_and_count() {
        let d = gen_n(Distribution::Uniform, 100_000);
        assert_eq!(d.len(), 100_000);
        let v = d.to_vec();
        assert!(v.iter().all(|&x| (KEY_LO..KEY_HI).contains(&(x as i64))));
        // both halves populated
        assert!(v.iter().any(|&x| x < 0) && v.iter().any(|&x| x > 0));
    }

    #[test]
    fn uniform_mean_near_zero() {
        let d = gen_n(Distribution::Uniform, 200_000);
        let mean: f64 =
            d.iter().map(|&x| x as f64).sum::<f64>() / d.len() as f64;
        assert!(
            mean.abs() < 2e7,
            "uniform mean {mean:.0} too far from 0 (≈1% of range)"
        );
    }

    #[test]
    fn zipf_is_heavily_skewed() {
        let d = gen_n(Distribution::Zipf, 50_000);
        let mut counts = std::collections::HashMap::new();
        for &v in d.iter() {
            *counts.entry(v).or_insert(0u64) += 1;
        }
        let max = counts.values().max().copied().unwrap();
        // s=2.5: the top value should dominate (>40% of mass)
        assert!(
            max as f64 > 0.4 * d.len() as f64,
            "zipf top value only {max}/{}",
            d.len()
        );
        assert!(counts.len() > 10, "zipf degenerate: {} distinct", counts.len());
    }

    #[test]
    fn bimodal_two_lobes() {
        let d = gen_n(Distribution::Bimodal, 100_000);
        let v = d.to_vec();
        let near_neg = v.iter().filter(|&&x| (x as f64 + 3.33e8).abs() < 2e8).count();
        let near_pos = v.iter().filter(|&&x| (x as f64 - 3.33e8).abs() < 2e8).count();
        let near_zero = v.iter().filter(|&&x| (x as f64).abs() < 5e7).count();
        assert!(near_neg > v.len() / 5 && near_pos > v.len() / 5);
        assert!(near_zero < near_neg / 2, "valley between modes missing");
    }

    #[test]
    fn sorted_bands_globally_ordered() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let d = Distribution::Sorted.generator(3).generate(&mut c, 80_000);
        for p in 0..d.num_partitions() {
            let part = d.partition(p);
            assert!(part.windows(2).all(|w| w[0] <= w[1]), "partition {p} unsorted");
            if p + 1 < d.num_partitions() {
                let next = d.partition(p + 1);
                if let (Some(&last), Some(&first)) = (part.last(), next.first()) {
                    assert!(last <= first, "bands overlap at {p}");
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = gen_n(Distribution::Uniform, 10_000).to_vec();
        let b = gen_n(Distribution::Uniform, 10_000).to_vec();
        assert_eq!(a, b);
    }

    #[test]
    fn remainder_distribution_exact() {
        let d = gen_n(Distribution::Uniform, 10_007);
        assert_eq!(d.len(), 10_007);
        let sizes = d.partition_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 10_007);
        assert!(sizes.iter().all(|&s| s == 1250 || s == 1251));
    }
}
