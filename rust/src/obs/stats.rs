//! Self-sketched stage statistics: per-stage task-latency summaries
//! computed by feeding attempt durations through our own
//! [`GkCore`](crate::sketch::GkCore) — the system measuring itself with
//! the algorithm it implements (the Moments-sketch framing: quantile
//! sketches as the backbone of telemetry aggregation).
//!
//! The input is `RunMetrics::stage_attempt_us` — one `Vec<u32>` of
//! per-task virtual-clock durations (µs) per `map_partitions` stage,
//! recorded unconditionally (independent of tracing). Stats ride every
//! `MetricsReport` and the BENCH json records.

use crate::sketch::GkCore;
use crate::Key;

/// ε of the latency sketch. Stage task counts are small (≤ partitions),
/// so a tight ε costs nothing and keeps the percentiles near-exact.
/// Shared with the engine-lifetime registry's per-kind folds
/// ([`crate::obs::registry::MetricsRegistry`]) so both layers quote the
/// same precision.
pub const STATS_EPSILON: f64 = 0.01;

/// Task-latency summary of one `map_partitions` stage: percentiles from
/// the GK sketch, maximum exact. Durations are virtual-clock µs, so the
/// numbers are deterministic and mode-independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageStats {
    /// Stage index within the run this report covers (0-based).
    pub stage: u64,
    /// Tasks (= partitions) the stage ran.
    pub tasks: u64,
    pub p50_us: u32,
    pub p95_us: u32,
    pub p99_us: u32,
    pub max_us: u32,
}

/// Summarize every stage of a run. Empty stages (none in practice — a
/// stage always runs ≥ 1 task) are skipped.
pub fn stage_stats(stage_attempt_us: &[Vec<u32>]) -> Vec<StageStats> {
    stage_attempt_us
        .iter()
        .enumerate()
        .filter(|(_, durs)| !durs.is_empty())
        .map(|(stage, durs)| {
            let mut sorted: Vec<Key> = durs.iter().map(|&d| d.min(i32::MAX as u32) as Key).collect();
            sorted.sort_unstable();
            let core = GkCore::from_sorted(&sorted, STATS_EPSILON);
            let pct = |q: f64| core.query_quantile(q).unwrap_or(0).max(0) as u32;
            StageStats {
                stage: stage as u64,
                tasks: durs.len() as u64,
                p50_us: pct(0.5),
                p95_us: pct(0.95),
                p99_us: pct(0.99),
                max_us: *sorted.last().expect("nonempty") as u32,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_yields_no_stats() {
        assert!(stage_stats(&[]).is_empty());
        assert!(stage_stats(&[Vec::new()]).is_empty());
    }

    #[test]
    fn single_task_stage_is_exact() {
        let stats = stage_stats(&[vec![1_500]]);
        assert_eq!(stats.len(), 1);
        let s = stats[0];
        assert_eq!((s.stage, s.tasks), (0, 1));
        assert_eq!(
            (s.p50_us, s.p95_us, s.p99_us, s.max_us),
            (1_500, 1_500, 1_500, 1_500)
        );
    }

    #[test]
    fn percentiles_track_the_distribution() {
        // 100 tasks: 99 take ~1000µs, one straggler takes 100_000µs
        let mut durs: Vec<u32> = (0..99).map(|i| 1_000 + i).collect();
        durs.push(100_000);
        let stats = stage_stats(&[durs]);
        let s = stats[0];
        assert_eq!(s.tasks, 100);
        assert!((1_000..1_100).contains(&s.p50_us), "p50 {}", s.p50_us);
        assert!(s.p95_us < 100_000, "p95 {} must exclude the straggler", s.p95_us);
        assert_eq!(s.max_us, 100_000, "max is exact");
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
    }

    #[test]
    fn stages_keep_their_index() {
        let stats = stage_stats(&[vec![10, 20], vec![30, 40, 50]]);
        assert_eq!(stats.len(), 2);
        assert_eq!((stats[0].stage, stats[0].tasks), (0, 2));
        assert_eq!((stats[1].stage, stats[1].tasks), (1, 3));
        assert_eq!(stats[1].max_us, 50);
    }
}
