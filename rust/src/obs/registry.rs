//! Engine-lifetime metrics registry.
//!
//! Every [`MetricsReport`] is a per-operation delta that dies with its
//! outcome; a long-lived streaming engine needs the cumulative view.
//! The [`MetricsRegistry`] is owned by
//! [`QuantileEngine`](crate::engine::QuantileEngine) and absorbs the
//! report of every `execute`/`ingest` into lifetime counters keyed by
//! [`OpKind`] × stream id, folds true per-task latencies into per-kind
//! [`GkCore`] sketches (the system monitoring itself with the algorithm
//! it implements), and samples **store-residency gauges** live from the
//! [`SketchStore`] — making the paper's two structural claims
//! continuously observable:
//!
//! * **band efficiency** — candidates actually shipped to the driver
//!   over the Σ 16εn+64 budgets they ran under, ≤ 1.0 by construction
//!   (the extract truncates at the budget): the no-full-shuffle claim
//!   as a scrapeable ratio;
//! * **store residency** — cached partial bytes, live vs sealed epoch
//!   counts, and compactions run: the O(P/ε) footprint claim as gauges.
//!
//! Exports: [`MetricsRegistry::render_prometheus`] (text exposition,
//! see [`crate::obs::prom`]) and an append-only JSON-lines query log
//! (see [`crate::obs::qlog`]). The mode is resolved with the standard
//! precedence — builder (`EngineBuilder::metrics`) > config file
//! (`[obs] metrics`) > env (`GKSELECT_METRICS`).

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;

use crate::cluster::metrics::MetricsReport;
use crate::sketch::GkCore;
use crate::stream::store::SketchStore;
use crate::Key;

use super::stats::STATS_EPSILON;
use super::{prom, qlog};

/// Accepted values for `--metrics` / `[obs] metrics` /
/// `GKSELECT_METRICS`.
pub const METRICS_GRAMMAR: &str = "off | memory | prom:<path> | qlog:<path>";

/// Where the registry's exports go — the resolved form of the
/// `--metrics` / `[obs] metrics` / `GKSELECT_METRICS` knob.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MetricsMode {
    /// No registry (the default): absorb is a no-op, snapshots are
    /// empty, nothing allocates.
    Off,
    /// Accumulate in memory only; read via
    /// [`QuantileEngine::metrics_snapshot`](crate::engine::QuantileEngine::metrics_snapshot)
    /// and [`MetricsRegistry::qlog_lines`].
    Memory,
    /// Accumulate and rewrite a Prometheus text-exposition file after
    /// every operation (always a complete scrape, like the Chrome trace
    /// writer).
    Prom(PathBuf),
    /// Accumulate and append one qlog JSON line per operation.
    Qlog(PathBuf),
}

impl std::str::FromStr for MetricsMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "off" => Ok(Self::Off),
            "memory" => Ok(Self::Memory),
            other => {
                if let Some(path) = other.strip_prefix("prom:") {
                    if path.is_empty() {
                        anyhow::bail!("prom: needs a path ({METRICS_GRAMMAR})");
                    }
                    return Ok(Self::Prom(PathBuf::from(path)));
                }
                if let Some(path) = other.strip_prefix("qlog:") {
                    if path.is_empty() {
                        anyhow::bail!("qlog: needs a path ({METRICS_GRAMMAR})");
                    }
                    return Ok(Self::Qlog(PathBuf::from(path)));
                }
                anyhow::bail!("unknown metrics mode '{other}' ({METRICS_GRAMMAR})")
            }
        }
    }
}

impl fmt::Display for MetricsMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Off => write!(f, "off"),
            Self::Memory => write!(f, "memory"),
            Self::Prom(p) => write!(f, "prom:{}", p.display()),
            Self::Qlog(p) => write!(f, "qlog:{}", p.display()),
        }
    }
}

/// What kind of operation a report describes — the registry's first
/// key dimension and the `kind` label of every Prometheus series.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpKind {
    /// Exact batch query over a `Source::Dataset`.
    Batch,
    /// Exact query served from a stream's cached sketches.
    Stream,
    /// Micro-batch ingest sealing an epoch.
    Ingest,
    /// ε-approximate answer straight from a sketch (no data scan).
    Sketched,
    /// Query answered from the sketch after a stage failure
    /// (`DegradePolicy::SketchAnswer`).
    Degraded,
}

impl OpKind {
    pub fn label(self) -> &'static str {
        match self {
            Self::Batch => "batch",
            Self::Stream => "stream",
            Self::Ingest => "ingest",
            Self::Sketched => "sketched",
            Self::Degraded => "degraded",
        }
    }

    /// Classify a report the way the registry keys it. This is the one
    /// shared rule — `QueryOutcome::op_kind()` and the engine's absorb
    /// hook both call it, so the accessor can never disagree with the
    /// registry's labels.
    pub fn classify(algorithm: &str, exact: bool, degraded: bool) -> Self {
        if degraded {
            Self::Degraded
        } else if algorithm == "Stream Ingest" {
            Self::Ingest
        } else if !exact {
            Self::Sketched
        } else if algorithm.starts_with("Stream") {
            Self::Stream
        } else {
            Self::Batch
        }
    }
}

/// Per-operation context the engine hands to
/// [`MetricsRegistry::absorb`] alongside the report: the key, the plan
/// shape for the qlog, and the trace join key when a sink is armed.
#[derive(Debug, Clone, Copy)]
pub struct OpContext<'a> {
    pub kind: OpKind,
    /// Stream id for stream-keyed operations, `None` for batch.
    pub stream: Option<&'a str>,
    /// Plan shape (`single` / `multi` / `rank` / `sketched` / `ingest`).
    pub plan: &'a str,
    /// The engine's trace sequence number, present iff a trace sink is
    /// armed — the qlog ↔ Chrome-trace join key (see [`crate::obs::qlog`]).
    pub trace: Option<u64>,
}

/// Lifetime totals of one (kind, stream) key: every counter a
/// [`MetricsReport`] carries, summed over operations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpTotals {
    /// Operations absorbed under this key.
    pub ops: u64,
    /// Σ records covered (`report.n`).
    pub records: u64,
    pub rounds: u64,
    pub stage_boundaries: u64,
    pub data_scans: u64,
    pub shuffles: u64,
    pub persists: u64,
    pub bytes_to_driver: u64,
    pub bytes_shuffled: u64,
    pub bytes_tree_reduced: u64,
    pub bytes_broadcast: u64,
    pub bytes_persisted: u64,
    pub messages: u64,
    pub faults_injected: u64,
    pub tasks_retried: u64,
    pub speculative_launched: u64,
    pub speculative_wins: u64,
    pub degraded_queries: u64,
    pub band_candidates: u64,
    pub band_budget: u64,
    /// Σ modelled elapsed seconds.
    pub elapsed_secs: f64,
    /// Σ real stage wall seconds.
    pub wall_stage_secs: f64,
}

impl OpTotals {
    fn add(&mut self, r: &MetricsReport) {
        self.ops += 1;
        self.records += r.n;
        self.rounds += r.rounds;
        self.stage_boundaries += r.stage_boundaries;
        self.data_scans += r.data_scans;
        self.shuffles += r.shuffles;
        self.persists += r.persists;
        self.bytes_to_driver += r.bytes_to_driver;
        self.bytes_shuffled += r.bytes_shuffled;
        self.bytes_tree_reduced += r.bytes_tree_reduced;
        self.bytes_broadcast += r.bytes_broadcast;
        self.bytes_persisted += r.bytes_persisted;
        self.messages += r.messages;
        self.faults_injected += r.faults_injected;
        self.tasks_retried += r.tasks_retried;
        self.speculative_launched += r.speculative_launched;
        self.speculative_wins += r.speculative_wins;
        self.degraded_queries += r.degraded_queries;
        self.band_candidates += r.band_candidates;
        self.band_budget += r.band_budget;
        self.elapsed_secs += r.elapsed_secs;
        self.wall_stage_secs += r.wall_stage_secs;
    }

    /// Fold another totals bin into this one (grand-total view).
    pub fn merge(&mut self, o: &OpTotals) {
        self.ops += o.ops;
        self.records += o.records;
        self.rounds += o.rounds;
        self.stage_boundaries += o.stage_boundaries;
        self.data_scans += o.data_scans;
        self.shuffles += o.shuffles;
        self.persists += o.persists;
        self.bytes_to_driver += o.bytes_to_driver;
        self.bytes_shuffled += o.bytes_shuffled;
        self.bytes_tree_reduced += o.bytes_tree_reduced;
        self.bytes_broadcast += o.bytes_broadcast;
        self.bytes_persisted += o.bytes_persisted;
        self.messages += o.messages;
        self.faults_injected += o.faults_injected;
        self.tasks_retried += o.tasks_retried;
        self.speculative_launched += o.speculative_launched;
        self.speculative_wins += o.speculative_wins;
        self.degraded_queries += o.degraded_queries;
        self.band_candidates += o.band_candidates;
        self.band_budget += o.band_budget;
        self.elapsed_secs += o.elapsed_secs;
        self.wall_stage_secs += o.wall_stage_secs;
    }

    /// Network traffic (four movement ledgers, no persists).
    pub fn bytes_moved(&self) -> u64 {
        self.bytes_to_driver + self.bytes_shuffled + self.bytes_tree_reduced + self.bytes_broadcast
    }

    /// All five ledgers: movement plus storage.
    pub fn bytes_total(&self) -> u64 {
        self.bytes_moved() + self.bytes_persisted
    }

    /// Lifetime band efficiency: Σ shipped / Σ budget, ≤ 1.0 always
    /// (each extract truncates at its budget); 0.0 with no extracts.
    pub fn band_efficiency(&self) -> f64 {
        if self.band_budget == 0 {
            0.0
        } else {
            self.band_candidates as f64 / self.band_budget as f64
        }
    }
}

/// Live residency of one stream in the [`SketchStore`], sampled at the
/// last absorb — the O(P/ε) claim as gauges.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StreamResidency {
    /// Epochs currently live (bounded by the compaction policy).
    pub live_epochs: u64,
    /// Epochs sealed over the stream's lifetime (monotone).
    pub sealed_epochs: u64,
    /// Cached GK partials currently held (`live_epochs × partitions`).
    pub sketch_partials: u64,
    /// Serialized bytes of those partials — the footprint compaction
    /// keeps `O(P/ε)`.
    pub sketch_bytes: u64,
    /// Payload bytes across live epochs.
    pub data_bytes: u64,
    /// Records across live epochs.
    pub records: u64,
    /// Compactions run over the stream's lifetime (monotone).
    pub compactions: u64,
}

impl StreamResidency {
    /// Sketch + payload footprint.
    pub fn store_bytes(&self) -> u64 {
        self.sketch_bytes + self.data_bytes
    }
}

/// Per-kind task-latency summary from the registry's folded GK sketch.
/// Percentiles are sketched (ε = 0.01), `max_us` exact — same contract
/// as [`super::StageStats`], but folded across the engine's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencySummary {
    pub kind: OpKind,
    /// Task attempts folded in.
    pub tasks: u64,
    pub p50_us: u32,
    pub p95_us: u32,
    pub p99_us: u32,
    pub max_us: u32,
}

/// One per-kind latency fold: our own GK sketch fed the raw per-task
/// durations of every absorbed report.
#[derive(Debug, Clone)]
struct LatencyFold {
    sketch: GkCore,
    tasks: u64,
    max_us: u32,
}

impl LatencyFold {
    fn new() -> Self {
        Self {
            sketch: GkCore::new(STATS_EPSILON),
            tasks: 0,
            max_us: 0,
        }
    }

    fn fold(&mut self, stage_attempt_us: &[Vec<u32>]) {
        let mut batch: Vec<Key> = stage_attempt_us
            .iter()
            .flatten()
            .map(|&d| d.min(i32::MAX as u32) as Key)
            .collect();
        if batch.is_empty() {
            return;
        }
        batch.sort_unstable();
        self.tasks += batch.len() as u64;
        self.max_us = self.max_us.max(*batch.last().expect("nonempty") as u32);
        self.sketch.merge_sorted_batch(&batch);
    }

    fn summary(&self, kind: OpKind) -> LatencySummary {
        let pct = |q: f64| self.sketch.query_quantile(q).unwrap_or(0).max(0) as u32;
        LatencySummary {
            kind,
            tasks: self.tasks,
            p50_us: pct(0.5),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            max_us: self.max_us,
        }
    }
}

/// Immutable view of the registry at one instant: everything the
/// Prometheus renderer needs, cheap to clone out of the engine.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Operations absorbed over the engine's lifetime.
    pub ops: u64,
    /// Executor-pool mode label (`sequential` / `threads`).
    pub exec_mode: String,
    /// Kernel backend SIMD lane width (8 = AVX2, 4 = SSE2, 1 = scalar).
    pub simd_lane_width: u64,
    /// Per-(kind, stream) lifetime totals, sorted by key. Batch
    /// operations use the empty stream id.
    pub totals: Vec<((OpKind, String), OpTotals)>,
    /// Per-kind folded task-latency summaries, sorted by kind.
    pub latency: Vec<LatencySummary>,
    /// Per-stream store residency at the last absorb, sorted by stream.
    pub residency: Vec<(String, StreamResidency)>,
    /// Queries currently executing in the serving layer (0 outside it).
    pub in_flight_queries: u64,
    /// Ingests queued or executing in the serving layer (0 outside it).
    pub ingest_queue_depth: u64,
}

impl MetricsSnapshot {
    /// Grand totals across every (kind, stream) key.
    pub fn grand(&self) -> OpTotals {
        let mut g = OpTotals::default();
        for (_, t) in &self.totals {
            g.merge(t);
        }
        g
    }

    /// Totals of one key, if any operation was absorbed under it.
    pub fn totals_for(&self, kind: OpKind, stream: &str) -> Option<&OpTotals> {
        self.totals
            .iter()
            .find(|((k, s), _)| *k == kind && s == stream)
            .map(|(_, t)| t)
    }
}

/// The engine-lifetime registry. `Off` mode is free: no allocation, no
/// counters, empty snapshots — mirroring `TraceSink::Null`.
#[derive(Debug)]
pub struct MetricsRegistry {
    mode: MetricsMode,
    exec_mode: String,
    simd_lane_width: u64,
    ops: u64,
    totals: BTreeMap<(OpKind, String), OpTotals>,
    latency: BTreeMap<OpKind, LatencyFold>,
    residency: BTreeMap<String, StreamResidency>,
    in_flight_queries: u64,
    ingest_queue_depth: u64,
    qlog: Vec<String>,
    qlog_writer: Option<qlog::QlogWriter>,
}

impl MetricsRegistry {
    /// Build a registry for the resolved mode. `exec_mode` and
    /// `simd_lane_width` become the constant `exec_mode` / `simd`
    /// labels of every exported series.
    pub fn new(mode: MetricsMode, exec_mode: &str, simd_lane_width: u64) -> Self {
        let qlog_writer = match &mode {
            MetricsMode::Qlog(path) => Some(qlog::QlogWriter::new(path.clone())),
            _ => None,
        };
        Self {
            mode,
            exec_mode: exec_mode.to_string(),
            simd_lane_width,
            ops: 0,
            totals: BTreeMap::new(),
            latency: BTreeMap::new(),
            residency: BTreeMap::new(),
            in_flight_queries: 0,
            ingest_queue_depth: 0,
            qlog: Vec::new(),
            qlog_writer,
        }
    }

    /// Whether the registry accumulates at all (mode ≠ `Off`).
    pub fn is_enabled(&self) -> bool {
        self.mode != MetricsMode::Off
    }

    /// The resolved mode.
    pub fn mode(&self) -> &MetricsMode {
        &self.mode
    }

    /// Operations absorbed so far.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// The buffered qlog lines, in operation order (also the content of
    /// the qlog file in `Qlog` mode — the buffer is kept in every armed
    /// mode so tests and `repro metrics` can dump it).
    pub fn qlog_lines(&self) -> &[String] {
        &self.qlog
    }

    /// Absorb one operation: fold its report into the lifetime totals
    /// and latency sketches, resample the store-residency gauges, and
    /// emit the operation's qlog record / rewritten Prometheus file per
    /// the mode. No-op (and allocation-free) when `Off`.
    pub fn absorb(
        &mut self,
        ctx: &OpContext<'_>,
        report: &MetricsReport,
        store: &SketchStore,
    ) -> anyhow::Result<()> {
        if !self.is_enabled() {
            return Ok(());
        }
        self.sample_store(store);
        self.absorb_with(ctx, report, std::iter::empty())
    }

    /// [`Self::absorb`] with residency supplied by the caller instead of
    /// sampled from a borrowable store — the serving layer's absorb hook
    /// (its per-stream stores live behind writer locks, so it samples
    /// residency from the snapshot each ingest publishes; query absorbs
    /// pass nothing, because a pinned — possibly stale — snapshot must
    /// never roll a monotone residency gauge backwards).
    pub fn absorb_with(
        &mut self,
        ctx: &OpContext<'_>,
        report: &MetricsReport,
        residency: impl IntoIterator<Item = (String, StreamResidency)>,
    ) -> anyhow::Result<()> {
        if !self.is_enabled() {
            return Ok(());
        }
        self.ops += 1;
        let key = (ctx.kind, ctx.stream.unwrap_or("").to_string());
        self.totals.entry(key).or_default().add(report);
        self.latency
            .entry(ctx.kind)
            .or_insert_with(LatencyFold::new)
            .fold(&report.stage_attempt_us);
        for (stream, r) in residency {
            self.residency.insert(stream, r);
        }

        let line = qlog::record(self.ops, ctx, report);
        if let Some(w) = &self.qlog_writer {
            w.append(&line)?;
        }
        self.qlog.push(line);
        if let MetricsMode::Prom(path) = &self.mode {
            std::fs::write(path, self.render_prometheus())?;
        }
        Ok(())
    }

    /// Update the serving-layer gauges: queries currently executing and
    /// ingests queued or executing. No-op when `Off`, like every other
    /// write.
    pub fn set_service_gauges(&mut self, in_flight_queries: u64, ingest_queue_depth: u64) {
        if !self.is_enabled() {
            return;
        }
        self.in_flight_queries = in_flight_queries;
        self.ingest_queue_depth = ingest_queue_depth;
    }

    /// Resample the residency gauges from the store's current state.
    fn sample_store(&mut self, store: &SketchStore) {
        for id in store.stream_ids() {
            let Some(state) = store.stream(id) else {
                continue;
            };
            self.residency.insert(
                id.to_string(),
                StreamResidency {
                    live_epochs: state.live_epochs() as u64,
                    sealed_epochs: state.sealed_epochs(),
                    sketch_partials: state.sketch_partials() as u64,
                    sketch_bytes: state.sketch_bytes(),
                    data_bytes: state.data_bytes(),
                    records: state.total_count(),
                    compactions: state.compactions,
                },
            );
        }
    }

    /// Clone out the current state (sorted, render-ready).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            ops: self.ops,
            exec_mode: self.exec_mode.clone(),
            simd_lane_width: self.simd_lane_width,
            totals: self
                .totals
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
            latency: self
                .latency
                .iter()
                .map(|(&kind, fold)| fold.summary(kind))
                .collect(),
            residency: self
                .residency
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
            in_flight_queries: self.in_flight_queries,
            ingest_queue_depth: self.ingest_queue_depth,
        }
    }

    /// Render the current state in Prometheus text exposition format.
    pub fn render_prometheus(&self) -> String {
        prom::render_prometheus(&self.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::metrics::RunMetrics;
    use std::str::FromStr;

    fn report(algorithm: &str, exact: bool) -> MetricsReport {
        let m = RunMetrics {
            rounds: 2,
            data_scans: 2,
            bytes_to_driver: 100,
            bytes_shuffled: 10,
            bytes_tree_reduced: 20,
            bytes_broadcast: 30,
            bytes_persisted: 5,
            band_candidates: 50,
            band_budget: 100,
            stage_attempt_us: vec![vec![100, 200], vec![300, 400]],
            ..Default::default()
        };
        MetricsReport::from_metrics(algorithm, 1_000, 4, 2, 0.5, &m, exact)
    }

    #[test]
    fn metrics_mode_grammar_roundtrips() {
        for s in ["off", "memory", "prom:/tmp/m.prom", "qlog:/tmp/q.jsonl"] {
            let m = MetricsMode::from_str(s).unwrap();
            let again = MetricsMode::from_str(&m.to_string()).unwrap();
            assert_eq!(m, again, "{s}");
        }
        assert_eq!(MetricsMode::from_str("off").unwrap(), MetricsMode::Off);
        assert_eq!(
            MetricsMode::from_str("prom:m.prom").unwrap(),
            MetricsMode::Prom(PathBuf::from("m.prom"))
        );
        assert!(MetricsMode::from_str("prom:").is_err());
        assert!(MetricsMode::from_str("qlog:").is_err());
        assert!(MetricsMode::from_str("statsd").is_err());
        assert!(MetricsMode::from_str("").is_err());
    }

    #[test]
    fn classify_matches_the_registry_vocabulary() {
        assert_eq!(OpKind::classify("GK Select", true, false), OpKind::Batch);
        assert_eq!(OpKind::classify("GK Multi-Select", true, false), OpKind::Batch);
        assert_eq!(OpKind::classify("Stream Query", true, false), OpKind::Stream);
        assert_eq!(OpKind::classify("Stream Query", false, false), OpKind::Sketched);
        assert_eq!(OpKind::classify("Stream Ingest", true, false), OpKind::Ingest);
        assert_eq!(OpKind::classify("GK Select", false, true), OpKind::Degraded);
        assert_eq!(OpKind::classify("Stream Query", true, true), OpKind::Degraded);
    }

    #[test]
    fn off_mode_is_invisible() {
        let mut reg = MetricsRegistry::new(MetricsMode::Off, "sequential", 1);
        let ctx = OpContext {
            kind: OpKind::Batch,
            stream: None,
            plan: "single",
            trace: None,
        };
        reg.absorb(&ctx, &report("GK Select", true), &SketchStore::default())
            .unwrap();
        assert!(!reg.is_enabled());
        assert_eq!(reg.ops(), 0);
        assert!(reg.qlog_lines().is_empty());
        let snap = reg.snapshot();
        assert_eq!(snap.ops, 0);
        assert!(snap.totals.is_empty());
        assert!(snap.latency.is_empty());
    }

    #[test]
    fn absorb_accumulates_per_key_totals_and_latency() {
        let mut reg = MetricsRegistry::new(MetricsMode::Memory, "sequential", 1);
        let store = SketchStore::default();
        let batch = OpContext {
            kind: OpKind::Batch,
            stream: None,
            plan: "single",
            trace: Some(1),
        };
        let stream = OpContext {
            kind: OpKind::Stream,
            stream: Some("s"),
            plan: "multi",
            trace: Some(2),
        };
        reg.absorb(&batch, &report("GK Select", true), &store).unwrap();
        reg.absorb(&batch, &report("GK Select", true), &store).unwrap();
        reg.absorb(&stream, &report("Stream Query", true), &store).unwrap();

        assert_eq!(reg.ops(), 3);
        assert_eq!(reg.qlog_lines().len(), 3);
        let snap = reg.snapshot();
        let b = snap.totals_for(OpKind::Batch, "").unwrap();
        assert_eq!(b.ops, 2);
        assert_eq!(b.rounds, 4);
        assert_eq!(b.bytes_moved(), 320);
        assert_eq!(b.bytes_total(), 330);
        assert!((b.band_efficiency() - 0.5).abs() < 1e-12);
        let s = snap.totals_for(OpKind::Stream, "s").unwrap();
        assert_eq!(s.ops, 1);
        // grand = 3 ops, every counter the per-key bins carry
        let g = snap.grand();
        assert_eq!(g.ops, 3);
        assert_eq!(g.rounds, 6);
        assert_eq!(g.records, 3_000);
        // latency folded per kind: 2 batch ops × 4 tasks, 1 stream op × 4
        let lat: Vec<(OpKind, u64)> = snap.latency.iter().map(|l| (l.kind, l.tasks)).collect();
        assert_eq!(lat, vec![(OpKind::Batch, 8), (OpKind::Stream, 4)]);
        let l = snap.latency[0];
        assert_eq!(l.max_us, 400);
        assert!(l.p50_us <= l.p95_us && l.p95_us <= l.p99_us && l.p99_us <= l.max_us);
    }

    #[test]
    fn qlog_mode_appends_to_the_file() {
        let dir = std::env::temp_dir().join("gkselect_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("q{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut reg = MetricsRegistry::new(MetricsMode::Qlog(path.clone()), "sequential", 1);
        let ctx = OpContext {
            kind: OpKind::Batch,
            stream: None,
            plan: "single",
            trace: None,
        };
        reg.absorb(&ctx, &report("GK Select", true), &SketchStore::default())
            .unwrap();
        reg.absorb(&ctx, &report("GK Select", true), &SketchStore::default())
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert_eq!(reg.qlog_lines().len(), 2, "buffer mirrors the file");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn prom_mode_rewrites_a_complete_scrape() {
        let dir = std::env::temp_dir().join("gkselect_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("m{}.prom", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut reg = MetricsRegistry::new(MetricsMode::Prom(path.clone()), "threads", 8);
        let ctx = OpContext {
            kind: OpKind::Batch,
            stream: None,
            plan: "single",
            trace: None,
        };
        reg.absorb(&ctx, &report("GK Select", true), &SketchStore::default())
            .unwrap();
        let scrape = std::fs::read_to_string(&path).unwrap();
        assert_eq!(scrape, reg.render_prometheus(), "file is the live render");
        assert!(scrape.contains("# TYPE gkselect_ops_total counter"));
        std::fs::remove_file(&path).unwrap();
    }
}
