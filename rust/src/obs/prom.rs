//! Prometheus text exposition of a [`MetricsSnapshot`].
//!
//! One function, [`render_prometheus`]: every metric family gets its
//! `# HELP` / `# TYPE` header, every series carries the four standard
//! labels (`kind`, `stream`, `exec_mode`, `simd`; store gauges drop
//! `kind` since they describe the store, not an operation), and series
//! within a family come out in sorted key order — the snapshot's maps
//! are BTreeMaps, so two renders of the same state are byte-identical
//! and scrapes diff cleanly. Validated in CI by `scripts/check_prom.py`
//! (TYPE/HELP presence, label syntax, counter monotonicity across
//! scrapes).

use super::registry::{MetricsSnapshot, OpTotals, StreamResidency};

/// Escape a label value per the exposition format (`\` → `\\`,
/// `"` → `\"`, newline → `\n`).
fn escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn head(out: &mut String, name: &str, help: &str, typ: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {typ}\n"));
}

fn line(out: &mut String, name: &str, labels: &[(&str, String)], value: impl std::fmt::Display) {
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape(v)))
        .collect();
    out.push_str(&format!("{name}{{{}}} {value}\n", body.join(",")));
}

/// Render the full exposition document: operation counters per
/// (kind, stream), the band-efficiency ratio, per-kind task-latency
/// summaries, and per-stream store-residency gauges.
pub fn render_prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let op_labels = |kind: &str, stream: &str| {
        vec![
            ("kind", kind.to_string()),
            ("stream", stream.to_string()),
            ("exec_mode", snap.exec_mode.clone()),
            ("simd", snap.simd_lane_width.to_string()),
        ]
    };
    let stream_labels = |stream: &str| {
        vec![
            ("stream", stream.to_string()),
            ("exec_mode", snap.exec_mode.clone()),
            ("simd", snap.simd_lane_width.to_string()),
        ]
    };

    // -- lifetime operation counters, one series per (kind, stream) key
    type Get = fn(&OpTotals) -> u64;
    let counters: &[(&str, &str, Get)] = &[
        (
            "gkselect_ops_total",
            "Operations absorbed by the engine-lifetime registry.",
            |t| t.ops,
        ),
        (
            "gkselect_records_total",
            "Records covered by absorbed operations.",
            |t| t.records,
        ),
        (
            "gkselect_rounds_total",
            "Driver synchronization rounds (BSP supersteps).",
            |t| t.rounds,
        ),
        (
            "gkselect_data_scans_total",
            "Linear passes over dataset partitions.",
            |t| t.data_scans,
        ),
        (
            "gkselect_shuffles_total",
            "Full range-partition shuffles (0 on every GK Select path).",
            |t| t.shuffles,
        ),
        (
            "gkselect_persists_total",
            "Explicit persists of intermediate datasets.",
            |t| t.persists,
        ),
        (
            "gkselect_messages_total",
            "Messages sent on the fabric.",
            |t| t.messages,
        ),
        (
            "gkselect_faults_injected_total",
            "Injected faults that actually fired.",
            |t| t.faults_injected,
        ),
        (
            "gkselect_tasks_retried_total",
            "Task re-launches after failed attempts.",
            |t| t.tasks_retried,
        ),
        (
            "gkselect_speculative_launched_total",
            "Speculative duplicates launched against stragglers.",
            |t| t.speculative_launched,
        ),
        (
            "gkselect_speculative_wins_total",
            "Speculative duplicates that beat the straggler.",
            |t| t.speculative_wins,
        ),
        (
            "gkselect_degraded_queries_total",
            "Queries answered from the sketch after a stage failure.",
            |t| t.degraded_queries,
        ),
        (
            "gkselect_band_candidates_total",
            "Band candidates shipped to the driver by fused extracts.",
            |t| t.band_candidates,
        ),
        (
            "gkselect_band_budget_total",
            "Sum of the 16*eps*n+64 candidate budgets those extracts ran under.",
            |t| t.band_budget,
        ),
    ];
    for (name, help, get) in counters {
        head(&mut out, name, help, "counter");
        for ((kind, stream), t) in &snap.totals {
            line(&mut out, name, &op_labels(kind.label(), stream), get(t));
        }
    }

    // -- the five byte ledgers, disambiguated by the `ledger` label
    head(
        &mut out,
        "gkselect_bytes_total",
        "Bytes handled, by ledger: to_driver/shuffled/tree_reduced/broadcast move on the network, persisted is storage.",
        "counter",
    );
    type LedgerGet = fn(&OpTotals) -> u64;
    let ledgers: &[(&str, LedgerGet)] = &[
        ("broadcast", |t| t.bytes_broadcast),
        ("persisted", |t| t.bytes_persisted),
        ("shuffled", |t| t.bytes_shuffled),
        ("to_driver", |t| t.bytes_to_driver),
        ("tree_reduced", |t| t.bytes_tree_reduced),
    ];
    for ((kind, stream), t) in &snap.totals {
        for (ledger, get) in ledgers {
            let mut labels = op_labels(kind.label(), stream);
            labels.push(("ledger", ledger.to_string()));
            line(&mut out, "gkselect_bytes_total", &labels, get(t));
        }
    }

    // -- modelled elapsed seconds per key
    head(
        &mut out,
        "gkselect_op_seconds_total",
        "Modelled elapsed seconds of absorbed operations.",
        "counter",
    );
    for ((kind, stream), t) in &snap.totals {
        line(
            &mut out,
            "gkselect_op_seconds_total",
            &op_labels(kind.label(), stream),
            t.elapsed_secs,
        );
    }

    // -- the paper's no-full-shuffle claim as a live ratio
    head(
        &mut out,
        "gkselect_band_efficiency_ratio",
        "Band candidates shipped over the 16*eps*n+64 budget; <= 1.0 by construction.",
        "gauge",
    );
    for ((kind, stream), t) in &snap.totals {
        line(
            &mut out,
            "gkselect_band_efficiency_ratio",
            &op_labels(kind.label(), stream),
            t.band_efficiency(),
        );
    }

    // -- per-kind task-latency summaries from the registry's GK folds
    head(
        &mut out,
        "gkselect_tasks_total",
        "Task attempts folded into the per-kind latency sketch.",
        "counter",
    );
    for l in &snap.latency {
        line(
            &mut out,
            "gkselect_tasks_total",
            &op_labels(l.kind.label(), ""),
            l.tasks,
        );
    }
    head(
        &mut out,
        "gkselect_task_latency_us",
        "Per-kind task latency percentiles (virtual-clock us) from the lifetime GK sketch.",
        "gauge",
    );
    for l in &snap.latency {
        for (q, v) in [("0.5", l.p50_us), ("0.95", l.p95_us), ("0.99", l.p99_us)] {
            let mut labels = op_labels(l.kind.label(), "");
            labels.push(("quantile", q.to_string()));
            line(&mut out, "gkselect_task_latency_us", &labels, v);
        }
    }
    head(
        &mut out,
        "gkselect_task_latency_max_us",
        "Per-kind maximum task latency (exact, virtual-clock us).",
        "gauge",
    );
    for l in &snap.latency {
        line(
            &mut out,
            "gkselect_task_latency_max_us",
            &op_labels(l.kind.label(), ""),
            l.max_us,
        );
    }

    // -- store residency: the O(P/eps) footprint claim as gauges
    type ResGet = fn(&StreamResidency) -> u64;
    let gauges: &[(&str, &str, &str, ResGet)] = &[
        (
            "gkselect_store_live_epochs",
            "Live epochs currently held (bounded by the compaction policy).",
            "gauge",
            |r| r.live_epochs,
        ),
        (
            "gkselect_store_sealed_epochs_total",
            "Epochs sealed over the stream's lifetime.",
            "counter",
            |r| r.sealed_epochs,
        ),
        (
            "gkselect_store_sketch_partials",
            "Cached GK partials currently held (live_epochs x partitions).",
            "gauge",
            |r| r.sketch_partials,
        ),
        (
            "gkselect_store_sketch_bytes",
            "Serialized bytes of cached partials (the O(P/eps) footprint).",
            "gauge",
            |r| r.sketch_bytes,
        ),
        (
            "gkselect_store_data_bytes",
            "Payload bytes across live epochs.",
            "gauge",
            |r| r.data_bytes,
        ),
        (
            "gkselect_store_bytes",
            "Store footprint: cached sketches plus payload.",
            "gauge",
            |r| r.store_bytes(),
        ),
        (
            "gkselect_store_records",
            "Records across live epochs.",
            "gauge",
            |r| r.records,
        ),
        (
            "gkselect_store_compactions_total",
            "Compactions run over the stream's lifetime.",
            "counter",
            |r| r.compactions,
        ),
    ];
    for (name, help, typ, get) in gauges {
        head(&mut out, name, help, typ);
        for (stream, r) in &snap.residency {
            line(&mut out, name, &stream_labels(stream), get(r));
        }
    }

    // -- serving-layer load gauges (one series each; only once the
    //    registry has absorbed something, so an empty snapshot stays
    //    headers-only)
    let svc_labels = vec![
        ("exec_mode", snap.exec_mode.clone()),
        ("simd", snap.simd_lane_width.to_string()),
    ];
    head(
        &mut out,
        "gkselect_service_in_flight_queries",
        "Queries currently executing in the serving layer.",
        "gauge",
    );
    if snap.ops > 0 {
        line(
            &mut out,
            "gkselect_service_in_flight_queries",
            &svc_labels,
            snap.in_flight_queries,
        );
    }
    head(
        &mut out,
        "gkselect_service_ingest_queue_depth",
        "Ingests queued or executing in the serving layer.",
        "gauge",
    );
    if snap.ops > 0 {
        line(
            &mut out,
            "gkselect_service_ingest_queue_depth",
            &svc_labels,
            snap.ingest_queue_depth,
        );
    }

    out
}

#[cfg(test)]
mod tests {
    use super::super::registry::{LatencySummary, OpKind};
    use super::*;

    fn snapshot() -> MetricsSnapshot {
        let batch = OpTotals {
            ops: 2,
            rounds: 4,
            bytes_to_driver: 100,
            band_candidates: 50,
            band_budget: 100,
            ..Default::default()
        };
        let stream = OpTotals {
            ops: 1,
            rounds: 1,
            ..Default::default()
        };
        MetricsSnapshot {
            ops: 3,
            exec_mode: "sequential".into(),
            simd_lane_width: 8,
            totals: vec![
                ((OpKind::Batch, String::new()), batch),
                ((OpKind::Stream, "s".into()), stream),
            ],
            latency: vec![LatencySummary {
                kind: OpKind::Batch,
                tasks: 8,
                p50_us: 100,
                p95_us: 300,
                p99_us: 400,
                max_us: 400,
            }],
            residency: vec![(
                "s".into(),
                StreamResidency {
                    live_epochs: 2,
                    sealed_epochs: 5,
                    sketch_partials: 8,
                    sketch_bytes: 1024,
                    data_bytes: 4096,
                    records: 1000,
                    compactions: 1,
                },
            )],
            in_flight_queries: 3,
            ingest_queue_depth: 1,
        }
    }

    #[test]
    fn render_is_stable_and_headed() {
        let snap = snapshot();
        let a = render_prometheus(&snap);
        let b = render_prometheus(&snap);
        assert_eq!(a, b, "same snapshot renders byte-identically");
        // every series line belongs to a family with HELP and TYPE
        for name in [
            "gkselect_ops_total",
            "gkselect_rounds_total",
            "gkselect_bytes_total",
            "gkselect_band_efficiency_ratio",
            "gkselect_task_latency_us",
            "gkselect_store_sketch_bytes",
            "gkselect_store_sealed_epochs_total",
        ] {
            assert!(a.contains(&format!("# HELP {name} ")), "{name} HELP");
            assert!(a.contains(&format!("# TYPE {name} ")), "{name} TYPE");
        }
        assert!(a.contains(
            "gkselect_ops_total{kind=\"batch\",stream=\"\",exec_mode=\"sequential\",simd=\"8\"} 2"
        ));
        assert!(a.contains(
            "gkselect_ops_total{kind=\"stream\",stream=\"s\",exec_mode=\"sequential\",simd=\"8\"} 1"
        ));
        assert!(a.contains("gkselect_band_efficiency_ratio{kind=\"batch\",stream=\"\",exec_mode=\"sequential\",simd=\"8\"} 0.5"));
        assert!(a.contains("ledger=\"persisted\""));
        assert!(a.contains("quantile=\"0.95\""));
        assert!(a.contains(
            "gkselect_store_live_epochs{stream=\"s\",exec_mode=\"sequential\",simd=\"8\"} 2"
        ));
        assert!(a.contains(
            "gkselect_service_in_flight_queries{exec_mode=\"sequential\",simd=\"8\"} 3"
        ));
        assert!(a.contains(
            "gkselect_service_ingest_queue_depth{exec_mode=\"sequential\",simd=\"8\"} 1"
        ));
    }

    #[test]
    fn label_values_escape() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn empty_snapshot_renders_headers_only() {
        let text = render_prometheus(&MetricsSnapshot::default());
        assert!(text.contains("# TYPE gkselect_ops_total counter"));
        for l in text.lines() {
            assert!(l.starts_with('#'), "no series without data: {l}");
        }
    }
}
