//! Structured query log: one JSON line per engine operation.
//!
//! The qlog is the registry's event-level export — where Prometheus
//! exposition ([`crate::obs::prom`]) aggregates, the qlog records every
//! `execute`/`ingest` individually: operation kind, plan shape, outcome,
//! duration, and the byte ledgers, one self-contained JSON object per
//! line so `jq`/`grep` work without a parser state machine.
//!
//! ## Join key with PR-7 traces
//!
//! When a trace sink is armed ([`crate::obs::TraceSink`] ≠ `Null`) each
//! record carries a `"trace"` field: the engine's monotone trace
//! sequence number, the same value stamped as the `trace` attribute on
//! the root span of the corresponding Chrome/in-memory trace. Joining a
//! qlog line to its span tree is `qlog.trace == root_span.attrs["trace"]`.
//! With no sink armed the field is omitted — there is no trace to join.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::cluster::metrics::MetricsReport;
use crate::util::benchkit::JsonVal;

use super::registry::{OpContext, OpKind};

/// Render one qlog record as a single JSON line (no trailing newline).
///
/// `seq` is the registry's operation counter (1-based), so lines are
/// totally ordered even after log rotation or concatenation.
pub fn record(seq: u64, ctx: &OpContext<'_>, report: &MetricsReport) -> String {
    let mut fields: Vec<(&str, JsonVal)> = vec![
        ("seq", JsonVal::U64(seq)),
        ("op", JsonVal::Str(ctx.kind.label().to_string())),
        ("plan", JsonVal::Str(ctx.plan.to_string())),
        ("algorithm", JsonVal::Str(report.algorithm.clone())),
        (
            "outcome",
            JsonVal::Str(
                if ctx.kind == OpKind::Degraded {
                    "degraded"
                } else {
                    "ok"
                }
                .to_string(),
            ),
        ),
        ("exact", JsonVal::Bool(report.exact)),
        ("n", JsonVal::U64(report.n)),
        ("duration_s", JsonVal::F64(report.elapsed_secs)),
        ("rounds", JsonVal::U64(report.rounds)),
        ("data_scans", JsonVal::U64(report.data_scans)),
        ("shuffles", JsonVal::U64(report.shuffles)),
        ("persists", JsonVal::U64(report.persists)),
        ("bytes_moved", JsonVal::U64(report.network_volume_bytes)),
        ("bytes_persisted", JsonVal::U64(report.bytes_persisted)),
        ("bytes_total", JsonVal::U64(report.bytes_total())),
        ("band_candidates", JsonVal::U64(report.band_candidates)),
        ("band_budget", JsonVal::U64(report.band_budget)),
        ("band_efficiency", JsonVal::F64(report.band_efficiency())),
        ("faults_injected", JsonVal::U64(report.faults_injected)),
        ("tasks_retried", JsonVal::U64(report.tasks_retried)),
    ];
    if let Some(stream) = ctx.stream {
        fields.push(("stream", JsonVal::Str(stream.to_string())));
    }
    if let Some(trace) = ctx.trace {
        fields.push(("trace", JsonVal::U64(trace)));
    }
    JsonVal::obj(fields).render()
}

/// Append-only qlog file writer. Each [`append`](Self::append) opens the
/// file in append mode, writes one line, and closes it — operations are
/// engine-level (a handful per second at most), so durability per line
/// beats a held handle, and concatenating logs from restarted engines
/// stays valid.
#[derive(Debug)]
pub struct QlogWriter {
    path: PathBuf,
}

impl QlogWriter {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one rendered record as a line.
    pub fn append(&self, line: &str) -> std::io::Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{line}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::metrics::RunMetrics;
    use crate::util::minijson;

    fn report() -> MetricsReport {
        let m = RunMetrics {
            rounds: 2,
            data_scans: 2,
            bytes_to_driver: 100,
            bytes_persisted: 7,
            band_candidates: 10,
            band_budget: 40,
            ..Default::default()
        };
        MetricsReport::from_metrics("GK Select", 1_000, 4, 2, 0.25, &m, true)
    }

    #[test]
    fn record_is_one_parseable_json_line() {
        let ctx = OpContext {
            kind: OpKind::Batch,
            stream: None,
            plan: "single",
            trace: Some(3),
        };
        let line = record(1, &ctx, &report());
        assert!(!line.contains('\n'), "one line per record");
        let doc = minijson::parse(&line).unwrap();
        assert_eq!(doc.get("seq").unwrap().as_u64(), Some(1));
        assert_eq!(doc.get("op").unwrap().as_str(), Some("batch"));
        assert_eq!(doc.get("plan").unwrap().as_str(), Some("single"));
        assert_eq!(doc.get("outcome").unwrap().as_str(), Some("ok"));
        assert_eq!(doc.get("trace").unwrap().as_u64(), Some(3));
        assert_eq!(doc.get("bytes_total").unwrap().as_u64(), Some(107));
        assert!(doc.get("stream").is_none(), "batch ops carry no stream");
    }

    #[test]
    fn trace_field_only_when_a_sink_is_armed() {
        let ctx = OpContext {
            kind: OpKind::Stream,
            stream: Some("s"),
            plan: "multi",
            trace: None,
        };
        let line = record(2, &ctx, &report());
        let doc = minijson::parse(&line).unwrap();
        assert!(doc.get("trace").is_none(), "no sink, no join key");
        assert_eq!(doc.get("stream").unwrap().as_str(), Some("s"));
    }

    #[test]
    fn degraded_kind_stamps_the_outcome() {
        let ctx = OpContext {
            kind: OpKind::Degraded,
            stream: Some("s"),
            plan: "single",
            trace: None,
        };
        let doc = minijson::parse(&record(3, &ctx, &report())).unwrap();
        assert_eq!(doc.get("outcome").unwrap().as_str(), Some("degraded"));
    }

    #[test]
    fn writer_appends_lines() {
        let dir = std::env::temp_dir().join("gkselect_qlog_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("q{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let w = QlogWriter::new(&path);
        w.append("{\"seq\":1}").unwrap();
        w.append("{\"seq\":2}").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines.iter().all(|l| minijson::parse(l).is_ok()));
        std::fs::remove_file(&path).unwrap();
    }
}
