//! Structured span tracing: per-query trace trees from the engine down
//! to individual task attempts.
//!
//! The counters in [`crate::cluster::metrics::MetricsReport`] assert the
//! protocol's *shape* (rounds, scans, retry tallies); this module shows
//! *where* time and retries go inside a query. Every
//! [`QuantileEngine::execute`](crate::engine::QuantileEngine::execute)
//! opens a root span (query kind, plan shape, ε, backend, SIMD lane
//! width); every `Cluster::map_partitions` stage and `tree_reduce`
//! opens a child span; every task **attempt** becomes a leaf span
//! carrying partition, executor, attempt number, and outcome
//! (`ok` / `panic` / `transient` / `lost` / `speculative-win` /
//! `speculative-loss`) — the fault layer's five counters, visible as
//! tree structure.
//!
//! Spans record both virtual-clock and real wall timestamps. Attempt
//! records are collected per executor and stitched in deterministic
//! `(partition, attempt)` order at stage end, so the span *tree* is
//! identical under `ExecMode::Sequential` and `ExecMode::Threads` and
//! tests can pin it. Finished traces drain into a pluggable
//! [`TraceSink`]:
//!
//! * [`TraceSink::Null`] — the default; the [`Tracer`] stays disabled
//!   and every hook is a no-op (measured ~zero overhead, gated by the
//!   `trace_overhead` bench record).
//! * [`TraceSink::InMemory`] — attaches the [`Trace`] to
//!   `QueryOutcome::trace()` for tests and programmatic inspection.
//! * [`TraceSink::Chrome`] — rewrites a Chrome-trace-event JSON file on
//!   every drain (always valid JSON, loadable in Perfetto / `chrome://tracing`).
//!
//! The mode is resolved with the standard precedence — builder
//! (`EngineBuilder::trace`) > config file (`[obs] trace`) > env
//! (`GKSELECT_TRACE`) — and exposed on the CLI as the global `--trace`
//! flag plus the `repro trace <workload>` subcommand.
//!
//! On top, [`StageStats`] summarizes per-stage task-latency
//! distributions (p50/p95/p99/max) by feeding attempt durations through
//! our own [`GkCore`](crate::sketch::GkCore) — the system measuring
//! itself with the algorithm it implements. Stats are always on
//! (independent of tracing) and ride every `MetricsReport`.
//!
//! ```
//! use gkselect::prelude::*;
//!
//! let mut engine = EngineBuilder::new()
//!     .cluster(ClusterConfig::local(2, 4))
//!     .algorithm(AlgoChoice::GkSelect)
//!     .trace(TraceMode::Memory)
//!     .build()
//!     .unwrap();
//! let data = UniformGen::new(42).generate(engine.cluster_mut(), 10_000);
//! let out = engine
//!     .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
//!     .unwrap();
//!
//! let trace = out.trace().expect("memory sink attaches the trace");
//! // fused batch protocol: one root query span, 2 stage spans under it
//! assert_eq!(trace.roots().count(), 1);
//! assert_eq!(trace.spans_of_kind(SpanKind::Stage).count(), 2);
//! // per-stage latency sketches ride the report unconditionally
//! assert_eq!(out.report.stage_stats.len(), 2);
//! ```
//!
//! ## Engine-lifetime metrics
//!
//! Traces show one query; the [`registry`] shows the engine's lifetime.
//! With a metrics mode armed ([`MetricsMode`], resolved builder >
//! `[obs] metrics` config > `GKSELECT_METRICS` env), every
//! `execute`/`ingest` report is absorbed into cumulative per-kind
//! counters, per-kind task-latency GK sketches, a live band-efficiency
//! ratio, and store-residency gauges — exported as Prometheus text
//! exposition ([`prom`]) and an append-only JSON-lines query log
//! ([`qlog`]):
//!
//! ```
//! use gkselect::prelude::*;
//!
//! let mut engine = EngineBuilder::new()
//!     .cluster(ClusterConfig::local(2, 4))
//!     .metrics(MetricsMode::Memory)
//!     .build()
//!     .unwrap();
//! let data = UniformGen::new(7).generate(engine.cluster_mut(), 5_000);
//! engine
//!     .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
//!     .unwrap();
//!
//! let snap = engine.metrics_snapshot();
//! assert_eq!(snap.ops, 1);
//! let batch = snap.totals_for(OpKind::Batch, "").unwrap();
//! // fused batch protocol: 2 rounds, 2 data scans, budget respected
//! assert_eq!((batch.rounds, batch.data_scans), (2, 2));
//! assert!(batch.band_efficiency() <= 1.0);
//! // and the snapshot renders as a Prometheus scrape
//! let scrape = engine.registry().render_prometheus();
//! assert!(scrape.contains("# TYPE gkselect_ops_total counter"));
//! ```

pub mod chrome;
pub mod prom;
pub mod qlog;
pub mod registry;
pub mod stats;

use std::fmt;
use std::path::PathBuf;
use std::time::Instant;

pub use chrome::ChromeTraceWriter;
pub use registry::{MetricsMode, MetricsRegistry, MetricsSnapshot, OpKind, METRICS_GRAMMAR};
pub use stats::StageStats;

/// What a span describes. `Query`/`StreamQuery`/`Ingest` are roots
/// opened by the engine; `Stage`/`Reduce` are driver-side children;
/// `Attempt` leaves are individual task attempts on an executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// A batch query (`Source::Dataset`).
    Query,
    /// A streamed query (`Source::Stream`) — cached-sketch serving path.
    StreamQuery,
    /// A micro-batch ingest sealing an epoch into the sketch store.
    Ingest,
    /// One `Cluster::map_partitions` stage (= one data scan).
    Stage,
    /// One `Cluster::tree_reduce` merge (driver rounds, no data scan).
    Reduce,
    /// One task attempt on one executor (leaf).
    Attempt,
}

impl SpanKind {
    pub fn label(self) -> &'static str {
        match self {
            Self::Query => "query",
            Self::StreamQuery => "stream-query",
            Self::Ingest => "ingest",
            Self::Stage => "stage",
            Self::Reduce => "reduce",
            Self::Attempt => "attempt",
        }
    }
}

/// How one task attempt ended. Mirrors the fault layer's ledger: a
/// retried fault leaves its failed attempt behind as a span with the
/// matching outcome, followed by the attempt that recovered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AttemptOutcome {
    /// Ran to completion, no fault.
    Ok,
    /// Panicked (injected or real) and was retried or failed the stage.
    Panic,
    /// Failed with an injected transient error.
    Transient,
    /// Killed by executor loss.
    Lost,
    /// The faster copy of a speculated straggler pair.
    SpeculativeWin,
    /// The slower copy of a speculated straggler pair.
    SpeculativeLoss,
}

impl AttemptOutcome {
    pub fn label(self) -> &'static str {
        match self {
            Self::Ok => "ok",
            Self::Panic => "panic",
            Self::Transient => "transient",
            Self::Lost => "lost",
            Self::SpeculativeWin => "speculative-win",
            Self::SpeculativeLoss => "speculative-loss",
        }
    }
}

/// One task attempt as observed inside the executor pool, before it is
/// stitched into the span tree at stage end. Produced by
/// `cluster/pool.rs` only when tracing is enabled.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptRecord {
    pub partition: usize,
    pub executor: usize,
    pub attempt: u32,
    pub outcome: AttemptOutcome,
    /// Virtual-clock seconds charged to this attempt.
    pub model_secs: f64,
    /// Real wall seconds the attempt took on this box.
    pub wall_secs: f64,
    /// Failure reason for non-`Ok` outcomes (matches `StageError::reason`).
    pub fault: Option<String>,
}

/// One node of the trace tree. `id` is 1-based within a trace;
/// `parent == 0` marks a root.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub id: u64,
    pub parent: u64,
    pub kind: SpanKind,
    pub name: String,
    /// Virtual-clock seconds at open/close.
    pub start_model_s: f64,
    pub end_model_s: f64,
    /// Real wall seconds since the tracer's epoch at open/close.
    pub start_wall_s: f64,
    pub end_wall_s: f64,
    /// Stage index (`Stage`/`Reduce`/`Attempt` spans).
    pub stage: Option<u64>,
    /// Partition and executor (`Attempt` spans).
    pub partition: Option<usize>,
    pub executor: Option<usize>,
    pub attempt: Option<u32>,
    pub outcome: Option<AttemptOutcome>,
    /// Free-form key/value attributes (plan shape, ε, backend, ...).
    pub attrs: Vec<(String, String)>,
}

/// A finished trace: the spans of one query (or ingest), in open order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
}

impl Trace {
    /// Spans with no parent — exactly one per query in a well-formed trace.
    pub fn roots(&self) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(|s| s.parent == 0)
    }

    pub fn spans_of_kind(&self, kind: SpanKind) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(move |s| s.kind == kind)
    }

    /// Lookup by span id (ids are 1-based and dense).
    pub fn span(&self, id: u64) -> Option<&Span> {
        if id == 0 {
            return None;
        }
        self.spans.get(id as usize - 1).filter(|s| s.id == id)
    }

    pub fn children(&self, id: u64) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(move |s| s.parent == id)
    }

    /// Structural well-formedness: every non-root parent id resolves to
    /// an earlier span, every `Attempt` hangs off a `Stage` or `Reduce`,
    /// and every `Stage`/`Reduce` hangs off a root kind (or is itself a
    /// root when the cluster is driven without an engine).
    pub fn is_well_formed(&self) -> bool {
        self.spans.iter().all(|s| {
            if s.parent == 0 {
                return s.kind != SpanKind::Attempt;
            }
            let Some(p) = self.span(s.parent) else {
                return false;
            };
            if p.id >= s.id {
                return false;
            }
            match s.kind {
                SpanKind::Attempt => matches!(p.kind, SpanKind::Stage | SpanKind::Reduce),
                SpanKind::Stage | SpanKind::Reduce => matches!(
                    p.kind,
                    SpanKind::Query | SpanKind::StreamQuery | SpanKind::Ingest
                ),
                _ => false,
            }
        })
    }
}

/// The span collector owned by every `Cluster`. All hooks are no-ops
/// while disabled (the `TraceSink::Null` default), so the tracing layer
/// costs nothing when off.
#[derive(Debug, Clone)]
pub struct Tracer {
    enabled: bool,
    spans: Vec<Span>,
    /// Open-span stack: `open` parents under the top, `close` pops.
    stack: Vec<u64>,
    /// Wall-clock origin for `start_wall_s`/`end_wall_s`.
    epoch: Instant,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::disabled()
    }
}

impl Tracer {
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            spans: Vec::new(),
            stack: Vec::new(),
            epoch: Instant::now(),
        }
    }

    /// Arm or disarm span collection. Disarming drops any buffered spans
    /// so a later re-arm starts a clean trace.
    pub fn set_enabled(&mut self, on: bool) {
        if !on {
            self.spans.clear();
            self.stack.clear();
        }
        self.enabled = on;
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Open a span under the current stack top (root if the stack is
    /// empty). Returns the span id, or 0 when disabled — every other
    /// hook treats id 0 as a no-op, so call sites never branch.
    pub fn open(&mut self, kind: SpanKind, name: impl Into<String>, model_now: f64) -> u64 {
        if !self.enabled {
            return 0;
        }
        let id = self.spans.len() as u64 + 1;
        let wall = self.epoch.elapsed().as_secs_f64();
        self.spans.push(Span {
            id,
            parent: self.stack.last().copied().unwrap_or(0),
            kind,
            name: name.into(),
            start_model_s: model_now,
            end_model_s: model_now,
            start_wall_s: wall,
            end_wall_s: wall,
            stage: None,
            partition: None,
            executor: None,
            attempt: None,
            outcome: None,
            attrs: Vec::new(),
        });
        self.stack.push(id);
        id
    }

    /// Stamp the stage index onto an open `Stage`/`Reduce` span.
    pub fn set_stage(&mut self, id: u64, stage: u64) {
        if let Some(s) = self.get_mut(id) {
            s.stage = Some(stage);
        }
    }

    /// Attach a key/value attribute to an open span.
    pub fn attr(&mut self, id: u64, key: &str, value: impl fmt::Display) {
        let text = value.to_string();
        if let Some(s) = self.get_mut(id) {
            s.attrs.push((key.to_string(), text));
        }
    }

    /// Close span `id`, recording end timestamps and unwinding the open
    /// stack to its parent.
    pub fn close(&mut self, id: u64, model_now: f64) {
        if id == 0 {
            return;
        }
        let wall = self.epoch.elapsed().as_secs_f64();
        if let Some(pos) = self.stack.iter().rposition(|&s| s == id) {
            self.stack.truncate(pos);
        }
        if let Some(s) = self.get_mut(id) {
            s.end_model_s = model_now;
            s.end_wall_s = wall;
        }
    }

    /// Stitch the attempt records of a finished stage under its span, in
    /// deterministic `(partition, attempt, outcome)` order — the same
    /// tree regardless of executor scheduling, so `Sequential` and
    /// `Threads` traces are structurally identical.
    pub fn record_attempts(&mut self, stage_id: u64, records: &[AttemptRecord]) {
        if !self.enabled || stage_id == 0 {
            return;
        }
        let Some(stage_span) = self.span_ref(stage_id) else {
            return;
        };
        let (stage_index, sm, sw) = (
            stage_span.stage,
            stage_span.start_model_s,
            stage_span.start_wall_s,
        );
        let mut ordered: Vec<&AttemptRecord> = records.iter().collect();
        ordered.sort_by_key(|r| (r.partition, r.attempt, r.outcome));
        for r in ordered {
            let id = self.spans.len() as u64 + 1;
            self.spans.push(Span {
                id,
                parent: stage_id,
                kind: SpanKind::Attempt,
                name: format!("task p{} a{} {}", r.partition, r.attempt, r.outcome.label()),
                start_model_s: sm,
                end_model_s: sm + r.model_secs,
                start_wall_s: sw,
                end_wall_s: sw + r.wall_secs,
                stage: stage_index,
                partition: Some(r.partition),
                executor: Some(r.executor),
                attempt: Some(r.attempt),
                outcome: Some(r.outcome),
                attrs: r
                    .fault
                    .iter()
                    .map(|f| ("fault".to_string(), f.clone()))
                    .collect(),
            });
        }
    }

    /// Take the finished trace, leaving the tracer empty and still
    /// armed. Returns `None` while disabled.
    pub fn take(&mut self) -> Option<Trace> {
        if !self.enabled {
            return None;
        }
        self.stack.clear();
        Some(Trace {
            spans: std::mem::take(&mut self.spans),
        })
    }

    fn span_ref(&self, id: u64) -> Option<&Span> {
        if id == 0 {
            return None;
        }
        self.spans.get(id as usize - 1)
    }

    fn get_mut(&mut self, id: u64) -> Option<&mut Span> {
        if id == 0 || !self.enabled {
            return None;
        }
        self.spans.get_mut(id as usize - 1)
    }
}

/// Accepted values for `--trace` / `[obs] trace` / `GKSELECT_TRACE`.
pub const TRACE_GRAMMAR: &str = "off | memory | chrome:<path> | <path ending in .json>";

/// Where finished traces go — the resolved form of the `--trace` /
/// `[obs] trace` / `GKSELECT_TRACE` knob.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceMode {
    /// No tracing (the default): `TraceSink::Null`, hooks disabled.
    Off,
    /// Keep traces in memory, surfaced via `QueryOutcome::trace()`.
    Memory,
    /// Write a Chrome-trace-event JSON file (Perfetto-loadable).
    Chrome(PathBuf),
}

impl std::str::FromStr for TraceMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "off" => Ok(Self::Off),
            "memory" => Ok(Self::Memory),
            other => {
                if let Some(path) = other.strip_prefix("chrome:") {
                    if path.is_empty() {
                        anyhow::bail!("chrome: needs a path ({TRACE_GRAMMAR})");
                    }
                    return Ok(Self::Chrome(PathBuf::from(path)));
                }
                if other.ends_with(".json") {
                    return Ok(Self::Chrome(PathBuf::from(other)));
                }
                anyhow::bail!("unknown trace mode '{other}' ({TRACE_GRAMMAR})")
            }
        }
    }
}

impl fmt::Display for TraceMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Off => write!(f, "off"),
            Self::Memory => write!(f, "memory"),
            Self::Chrome(p) => write!(f, "chrome:{}", p.display()),
        }
    }
}

/// Pluggable destination for finished traces. The engine drains its
/// cluster's tracer into the sink after every query and ingest.
#[derive(Debug)]
pub enum TraceSink {
    /// Discard everything; the tracer stays disabled (default).
    Null,
    /// Hand the trace back to the caller on the outcome.
    InMemory,
    /// Append to a Chrome-trace file, rewriting it whole on each drain
    /// so the file is valid JSON after every query.
    Chrome(ChromeTraceWriter),
}

impl TraceSink {
    pub fn from_mode(mode: TraceMode) -> Self {
        match mode {
            TraceMode::Off => Self::Null,
            TraceMode::Memory => Self::InMemory,
            TraceMode::Chrome(path) => Self::Chrome(ChromeTraceWriter::new(path)),
        }
    }

    /// Whether the tracer feeding this sink should collect spans.
    pub fn wants_spans(&self) -> bool {
        !matches!(self, Self::Null)
    }

    /// Drain `tracer` into this sink, returning the trace for the
    /// outcome (None under `Null`). Chrome write failures are hard
    /// errors: the caller asked for a file.
    pub fn drain(&mut self, tracer: &mut Tracer) -> anyhow::Result<Option<Trace>> {
        match self {
            Self::Null => {
                tracer.take();
                Ok(None)
            }
            Self::InMemory => Ok(tracer.take()),
            Self::Chrome(writer) => match tracer.take() {
                None => Ok(None),
                Some(trace) => {
                    writer.append(&trace)?;
                    Ok(Some(trace))
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn disabled_tracer_is_all_noops() {
        let mut t = Tracer::disabled();
        let id = t.open(SpanKind::Query, "q", 0.0);
        assert_eq!(id, 0);
        t.attr(id, "k", "v");
        t.close(id, 1.0);
        t.record_attempts(
            id,
            &[AttemptRecord {
                partition: 0,
                executor: 0,
                attempt: 0,
                outcome: AttemptOutcome::Ok,
                model_secs: 0.0,
                wall_secs: 0.0,
                fault: None,
            }],
        );
        assert_eq!(t.take(), None);
    }

    #[test]
    fn open_close_builds_a_tree() {
        let mut t = Tracer::disabled();
        t.set_enabled(true);
        let root = t.open(SpanKind::Query, "q", 0.0);
        let stage = t.open(SpanKind::Stage, "stage 0", 0.0);
        t.set_stage(stage, 0);
        t.record_attempts(
            stage,
            &[
                AttemptRecord {
                    partition: 1,
                    executor: 1,
                    attempt: 0,
                    outcome: AttemptOutcome::Ok,
                    model_secs: 0.5,
                    wall_secs: 0.1,
                    fault: None,
                },
                AttemptRecord {
                    partition: 0,
                    executor: 0,
                    attempt: 0,
                    outcome: AttemptOutcome::Ok,
                    model_secs: 0.25,
                    wall_secs: 0.05,
                    fault: None,
                },
            ],
        );
        t.close(stage, 1.0);
        t.close(root, 2.0);
        let trace = t.take().unwrap();
        assert!(trace.is_well_formed());
        assert_eq!(trace.roots().count(), 1);
        assert_eq!(trace.spans_of_kind(SpanKind::Attempt).count(), 2);
        // attempts stitched in partition order regardless of arrival order
        let parts: Vec<usize> = trace
            .spans_of_kind(SpanKind::Attempt)
            .map(|s| s.partition.unwrap())
            .collect();
        assert_eq!(parts, vec![0, 1]);
        // attempt leaves hang off the stage, the stage off the root
        for a in trace.spans_of_kind(SpanKind::Attempt) {
            assert_eq!(a.parent, stage);
        }
        assert_eq!(trace.span(stage).unwrap().parent, root);
        // a second take starts a fresh trace with fresh ids
        let id = t.open(SpanKind::Query, "q2", 0.0);
        assert_eq!(id, 1);
    }

    #[test]
    fn trace_mode_grammar_roundtrips() {
        for s in ["off", "memory", "chrome:/tmp/t.json", "trace.json"] {
            let m = TraceMode::from_str(s).unwrap();
            let again = TraceMode::from_str(&m.to_string()).unwrap();
            assert_eq!(m, again, "{s}");
        }
        assert_eq!(TraceMode::from_str("off").unwrap(), TraceMode::Off);
        assert_eq!(
            TraceMode::from_str("t.json").unwrap(),
            TraceMode::Chrome(PathBuf::from("t.json"))
        );
        assert!(TraceMode::from_str("chrome:").is_err());
        assert!(TraceMode::from_str("perfetto").is_err());
        assert!(TraceMode::from_str("").is_err());
    }

    #[test]
    fn malformed_trees_are_rejected() {
        let mk = |kind, id, parent| Span {
            id,
            parent,
            kind,
            name: String::new(),
            start_model_s: 0.0,
            end_model_s: 0.0,
            start_wall_s: 0.0,
            end_wall_s: 0.0,
            stage: None,
            partition: None,
            executor: None,
            attempt: None,
            outcome: None,
            attrs: Vec::new(),
        };
        // attempt at the root
        let t = Trace {
            spans: vec![mk(SpanKind::Attempt, 1, 0)],
        };
        assert!(!t.is_well_formed());
        // attempt under another attempt
        let t = Trace {
            spans: vec![
                mk(SpanKind::Query, 1, 0),
                mk(SpanKind::Stage, 2, 1),
                mk(SpanKind::Attempt, 3, 2),
                mk(SpanKind::Attempt, 4, 3),
            ],
        };
        assert!(!t.is_well_formed());
        // dangling parent
        let t = Trace {
            spans: vec![mk(SpanKind::Query, 1, 0), mk(SpanKind::Stage, 2, 9)],
        };
        assert!(!t.is_well_formed());
        // a bare stage root is fine (cluster used without an engine)
        let t = Trace {
            spans: vec![mk(SpanKind::Stage, 1, 0)],
        };
        assert!(t.is_well_formed());
    }
}
