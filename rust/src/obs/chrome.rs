//! Chrome-trace-event export: one JSON file loadable in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`.
//!
//! Each drained [`Trace`] becomes one `pid` (so successive queries sit
//! side by side in the UI); driver spans (query / stage / reduce) run on
//! `tid 0`, attempt spans on `tid = executor + 1`. Every event is a
//! complete `"ph": "X"` duration event with wall-clock `ts`/`dur` in
//! microseconds, and carries `span_id` / `parent_id` plus the span's
//! typed fields under `args` so the tree can be reconstructed from the
//! file alone (`scripts/check_trace.py` validates exactly that).
//!
//! The writer rewrites the whole `{"traceEvents": [...]}` document on
//! every append, so the file on disk is valid JSON after every query —
//! there is no finalize step to forget.

use super::{Span, Trace};
use crate::util::benchkit::{write_json, JsonVal};
use std::io;
use std::path::{Path, PathBuf};

/// Accumulates trace events and rewrites the target file on each append.
#[derive(Debug)]
pub struct ChromeTraceWriter {
    path: PathBuf,
    events: Vec<JsonVal>,
    next_pid: u64,
}

impl ChromeTraceWriter {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self {
            path: path.into(),
            events: Vec::new(),
            next_pid: 1,
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of events written so far (across all appended traces).
    pub fn events_written(&self) -> usize {
        self.events.len()
    }

    /// Append one finished trace and rewrite the file.
    pub fn append(&mut self, trace: &Trace) -> io::Result<()> {
        let pid = self.next_pid;
        self.next_pid += 1;
        for span in &trace.spans {
            self.events.push(event(pid, span));
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        write_json(
            &self.path,
            &JsonVal::obj(vec![(
                "traceEvents",
                JsonVal::Arr(self.events.clone()),
            )]),
        )
    }
}

/// One span → one complete duration event.
fn event(pid: u64, s: &Span) -> JsonVal {
    let mut args = vec![
        ("span_id", JsonVal::U64(s.id)),
        ("parent_id", JsonVal::U64(s.parent)),
        ("kind", JsonVal::Str(s.kind.label().to_string())),
        ("start_model_s", JsonVal::F64(s.start_model_s)),
        ("end_model_s", JsonVal::F64(s.end_model_s)),
    ];
    if let Some(stage) = s.stage {
        args.push(("stage", JsonVal::U64(stage)));
    }
    if let Some(p) = s.partition {
        args.push(("partition", JsonVal::U64(p as u64)));
    }
    if let Some(e) = s.executor {
        args.push(("executor", JsonVal::U64(e as u64)));
    }
    if let Some(a) = s.attempt {
        args.push(("attempt", JsonVal::U64(a as u64)));
    }
    if let Some(o) = s.outcome {
        args.push(("outcome", JsonVal::Str(o.label().to_string())));
    }
    for (k, v) in &s.attrs {
        args.push((k.as_str(), JsonVal::Str(v.clone())));
    }
    let tid = s.executor.map(|e| e as u64 + 1).unwrap_or(0);
    JsonVal::obj(vec![
        ("name", JsonVal::Str(s.name.clone())),
        ("cat", JsonVal::Str(s.kind.label().to_string())),
        ("ph", JsonVal::Str("X".to_string())),
        ("ts", JsonVal::F64(s.start_wall_s * 1e6)),
        (
            "dur",
            JsonVal::F64((s.end_wall_s - s.start_wall_s).max(0.0) * 1e6),
        ),
        ("pid", JsonVal::U64(pid)),
        ("tid", JsonVal::U64(tid)),
        ("args", JsonVal::obj(args)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{SpanKind, Tracer};
    use crate::util::minijson::parse;

    #[test]
    fn file_is_valid_json_after_every_append() {
        let dir = std::env::temp_dir().join("gkselect_chrome_writer_test");
        let path = dir.join("trace.json");
        let _ = std::fs::remove_dir_all(&dir);
        let mut w = ChromeTraceWriter::new(&path);

        let mut t = Tracer::disabled();
        t.set_enabled(true);
        for round in 1..=2u64 {
            let root = t.open(SpanKind::Query, format!("q{round}"), 0.0);
            let stage = t.open(SpanKind::Stage, "stage 0", 0.0);
            t.close(stage, 1.0);
            t.close(root, 2.0);
            let trace = t.take().unwrap();
            w.append(&trace).unwrap();

            let text = std::fs::read_to_string(&path).unwrap();
            let doc = parse(&text).unwrap();
            let events = match doc.get("traceEvents") {
                Some(crate::util::minijson::Json::Arr(events)) => events,
                other => panic!("traceEvents must be an array, got {other:?}"),
            };
            assert_eq!(events.len() as u64, 2 * round, "2 spans per query");
            for ev in events {
                for field in ["name", "cat", "ph", "ts", "dur", "pid", "tid", "args"] {
                    assert!(ev.get(field).is_some(), "missing {field}");
                }
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
