//! End-to-end validation driver (DESIGN.md §6, EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the stack on a real small workload:
//!
//! 1. loads the AOT artifacts through PJRT (L1 Pallas kernels → L2 jax →
//!    HLO text → rust runtime) when available, falling back to the native
//!    backend with a warning;
//! 2. generates a 10⁷-key workload across 40 partitions;
//! 3. runs all six algorithms through the one public entry point
//!    (`QuantileEngine::execute`);
//! 4. verifies every exact algorithm against a ground-truth sort and the
//!    PJRT count kernel against the native one;
//! 5. reports the paper's headline metric: GK Select's speedup over Full
//!    Sort, its round count, and its network volume.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use gkselect::cluster::metrics::human_bytes;
use gkselect::config::ReproConfig;
use gkselect::harness::{make_cluster, timed_run};
use gkselect::prelude::*;
use std::path::Path;

/// PJRT-vs-native kernel probe; only meaningful with the `pjrt` feature.
#[cfg(feature = "pjrt")]
fn probe_pjrt(artifacts: &Path) -> bool {
    use gkselect::runtime::PjrtBackend;
    match PjrtBackend::load(artifacts) {
        Ok(pjrt) => {
            let native = NativeBackend::new();
            let probe: Vec<i32> = (0..300_000).map(|i| (i * 2_654_435_761u64 as i64) as i32).collect();
            for pivot in [i32::MIN, -7, 0, 1 << 20, i32::MAX] {
                let a = pjrt.count_pivot(&probe, pivot);
                let b = native.count_pivot(&probe, pivot);
                assert_eq!(a, b, "PJRT and native kernels disagree at pivot {pivot}");
            }
            let (mn_p, mx_p) = pjrt.minmax(&probe).unwrap();
            let (mn_n, mx_n) = native.minmax(&probe).unwrap();
            assert_eq!((mn_p, mx_p), (mn_n, mx_n));
            println!("[1/4] PJRT artifacts loaded; count/minmax kernels match native bit-exactly");
            true
        }
        Err(e) => {
            println!("[1/4] PJRT artifacts unavailable ({e:#}); continuing native-only");
            false
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn probe_pjrt(_artifacts: &Path) -> bool {
    println!("[1/4] built without the `pjrt` feature; continuing native-only");
    false
}

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000_000);
    let artifacts = Path::new("artifacts");

    // ---- L1/L2/L3 composition check: PJRT vs native on real data ------
    let cfg = ReproConfig {
        backend: "native".into(),
        artifacts_dir: artifacts.to_path_buf(),
        ..Default::default()
    };
    let pjrt_available = probe_pjrt(artifacts);
    // the comparison matrix runs on the native backend (the perf path —
    // interpret-mode Pallas through XLA CPU is the correctness vehicle);
    // a separate PJRT-backed GK Select run below proves the AOT path
    // composes end-to-end

    // ---- workload -------------------------------------------------------
    let mut cluster = make_cluster(&cfg, 10);
    println!(
        "[2/4] generating {n} uniform keys across {} partitions...",
        cluster.cfg.partitions
    );
    let data = UniformGen::new(7).generate(&mut cluster, n);
    let truth = oracle_quantile(&data, 0.5).expect("nonempty");

    // ---- full comparison matrix ----------------------------------------
    println!("[3/4] running the full algorithm matrix at q = 0.5");
    println!(
        "{:<12} {:>12} {:>10} {:>8} {:>9} {:>12} {:>8}",
        "algorithm", "median", "model s", "wall s", "rounds", "net volume", "exact"
    );
    let mut results = Vec::new();
    for choice in AlgoChoice::ALL {
        // count-discard algorithms are wall-clock heavy at 1e7 on one
        // core; they still run — this is the e2e proof, not a bench
        let mut engine = EngineBuilder::new()
            .config(cfg.clone())
            .algorithm(choice)
            .build()?;
        let (out, wall) = timed_run(&mut engine, &data, QuantileQuery::Single(0.5))?;
        if out.report.exact {
            assert_eq!(out.value(), truth, "{} exactness violated", choice.label());
        }
        println!(
            "{:<12} {:>12} {:>10.4} {:>8.2} {:>9} {:>12} {:>8}",
            out.report.algorithm,
            out.value(),
            out.report.elapsed_secs,
            wall,
            out.report.rounds,
            human_bytes(out.report.network_volume_bytes),
            out.report.exact
        );
        results.push((choice, out));
    }

    // ---- headline metric -------------------------------------------------
    let gk = &results
        .iter()
        .find(|(c, _)| *c == AlgoChoice::GkSelect)
        .unwrap()
        .1;
    let fs = &results
        .iter()
        .find(|(c, _)| *c == AlgoChoice::FullSort)
        .unwrap()
        .1;
    let sk = &results
        .iter()
        .find(|(c, _)| *c == AlgoChoice::GkSketch)
        .unwrap()
        .1;
    let speedup = fs.report.elapsed_secs / gk.report.elapsed_secs;
    let sketch_ratio = gk.report.elapsed_secs / sk.report.elapsed_secs;
    println!("\n[4/4] headline (paper: ≈10.5× over full sort @1e9/120p; sketch-level latency):");
    println!("  GK Select vs Full Sort : {speedup:.1}× faster (modelled, n = {n})");
    println!("  GK Select vs GK Sketch : {sketch_ratio:.2}× the sketch's latency");
    println!("  GK Select rounds = {}, shuffles = {}, persists = {}",
        gk.report.rounds, gk.report.shuffles, gk.report.persists);

    // ---- AOT path end-to-end: GK Select with the PJRT count kernel ------
    if pjrt_available {
        let mut pjrt_cfg = cfg.clone();
        pjrt_cfg.backend = "pjrt".into();
        let mut engine = EngineBuilder::new()
            .config(pjrt_cfg)
            .algorithm(AlgoChoice::GkSelect)
            .build()?;
        let (out, wall) = timed_run(&mut engine, &data, QuantileQuery::Single(0.5))?;
        assert_eq!(out.value(), truth, "PJRT-backed GK Select exactness");
        println!(
            "\nPJRT-backed GK Select: median {} (exact ✓), wall {wall:.2}s — \
             L1 Pallas → L2 jax → HLO text → L3 rust verified on the query path",
            out.value()
        );
    }

    println!("\ne2e pipeline OK — all exact algorithms matched the oracle ({truth})");
    Ok(())
}
