//! Quickstart: exact median of 10M uniform keys on a simulated 10-node
//! cluster, verified against a full-sort oracle and compared with the
//! approximate GK sketch.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gkselect::algorithms::oracle_quantile;
use gkselect::prelude::*;

fn main() -> anyhow::Result<()> {
    // A 10-node EMR-like cluster: 40 partitions, 10 Gbit fabric model.
    let mut cluster = Cluster::new(ClusterConfig::emr(10));

    println!("generating 10M uniform keys across 40 partitions...");
    let data = UniformGen::new(42).generate(&mut cluster, 10_000_000);

    // Exact quantile in 2 fused rounds.
    let mut gk = GkSelect::new(GkSelectParams::default());
    let exact = gk.quantile(&mut cluster, &data, 0.5)?;
    println!(
        "GK Select : median = {:>12}  rounds = {}  modelled = {:.3}s  net = {}",
        exact.value,
        exact.report.rounds,
        exact.report.elapsed_secs,
        gkselect::cluster::metrics::human_bytes(exact.report.network_volume_bytes),
    );

    // The approximate baseline for comparison.
    let mut sketch = ApproxQuantile::new(ApproxQuantileParams::default());
    let approx = sketch.quantile(&mut cluster, &data, 0.5)?;
    println!(
        "GK Sketch : median ≈ {:>12}  rounds = {}  modelled = {:.3}s",
        approx.value, approx.report.rounds, approx.report.elapsed_secs,
    );

    // Verify exactness.
    let truth = oracle_quantile(&data, 0.5).expect("nonempty");
    assert_eq!(exact.value, truth, "GK Select must equal the oracle");
    println!("verified: GK Select matches the full-sort oracle ({truth})");
    Ok(())
}
