//! Quickstart: exact median of 10M uniform keys on a simulated 10-node
//! cluster, verified against a full-sort oracle and compared with the
//! approximate GK sketch — all through the one `QuantileEngine` entry
//! point.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gkselect::prelude::*;

fn main() -> anyhow::Result<()> {
    // A 10-node EMR-like cluster: 40 partitions, 10 Gbit fabric model.
    let mut engine = EngineBuilder::new()
        .cluster(ClusterConfig::emr(10))
        .algorithm(AlgoChoice::GkSelect)
        .build()?;

    println!("generating 10M uniform keys across 40 partitions...");
    let data = UniformGen::new(42).generate(engine.cluster_mut(), 10_000_000);

    // Exact quantile in 2 fused rounds.
    let exact = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.5))?;
    println!(
        "GK Select : median = {:>12}  rounds = {}  modelled = {:.3}s  net = {}",
        exact.value(),
        exact.report.rounds,
        exact.report.elapsed_secs,
        gkselect::cluster::metrics::human_bytes(exact.report.network_volume_bytes),
    );

    // The approximate baseline: same engine, a `Sketched` plan.
    let approx = engine.execute(
        Source::Dataset(&data),
        QuantileQuery::Sketched { q: 0.5, eps: 0.01 },
    )?;
    println!(
        "GK Sketch : median ≈ {:>12}  rounds = {}  modelled = {:.3}s",
        approx.value(),
        approx.report.rounds,
        approx.report.elapsed_secs,
    );

    // Verify exactness.
    let truth = oracle_quantile(&data, 0.5).expect("nonempty");
    assert_eq!(exact.value(), truth, "GK Select must equal the oracle");
    println!("verified: GK Select matches the full-sort oracle ({truth})");
    Ok(())
}
