//! §Perf sketch-variant probe: insert throughput of ModifiedGk across
//! α values and SparkGk, on 1e7 random keys — the L3.3 sweep.
//!
//! ```bash
//! cargo run --release --example perf_sketch_sweep
//! ```
use gkselect::data::pcg::Pcg64;
use gkselect::sketch::modified::ModifiedGk;
use gkselect::sketch::spark::SparkGk;
use gkselect::sketch::QuantileSketch;
use std::time::Instant;
fn main() {
    let mut rng = Pcg64::new(1, 1);
    let xs: Vec<i32> = (0..10_000_000).map(|_| rng.next_u64() as i32).collect();
    for alpha in [2.0, 4.0, 8.0, 16.0, 32.0] {
        let t = Instant::now();
        let mut sk = ModifiedGk::with_alpha(0.01, alpha);
        for &v in &xs { sk.insert(v); }
        sk.finalize();
        println!("modified a={alpha:>4}: {:?} ({:.1} ns/key, |S|={}, B={})", t.elapsed(), t.elapsed().as_nanos() as f64 / xs.len() as f64, sk.summary_len(), sk.head_capacity());
    }
    let t = Instant::now();
    let mut sk = SparkGk::new(0.01);
    for &v in &xs { sk.insert(v); }
    sk.finalize();
    println!("spark B=50k  : {:?} ({:.1} ns/key, |S|={})", t.elapsed(), t.elapsed().as_nanos() as f64 / xs.len() as f64, sk.summary_len());
}
