//! Financial-risk scenario: exact p99 / p99.9 loss quantiles over a
//! skewed P&L distribution.
//!
//! Regulatory reporting (the paper's intro motivation) needs *exact*,
//! reproducible percentiles: an approximate p99.9 that drifts by εn ranks
//! can move a capital-requirement figure. This example builds a
//! heavy-tailed bimodal book (hedged longs/shorts), asks one engine for
//! the extreme loss quantiles — exact via `Single`, approximate via a
//! `Sketched` plan on the same call site — and shows the discrepancy the
//! sketch would have reported.
//!
//! ```bash
//! cargo run --release --example financial_risk
//! ```

use gkselect::prelude::*;

fn main() -> anyhow::Result<()> {
    // tight pivot: extreme quantiles live in thin tails
    let mut engine = EngineBuilder::new()
        .cluster(ClusterConfig::emr(10))
        .algorithm(AlgoChoice::GkSelect)
        .epsilon(0.005)
        .build()?;

    // Bimodal P&L: hedged book with two exposure lobes; values are basis
    // points × 1e4 (i32 range).
    println!("generating 20M P&L samples (bimodal, heavy lobes)...");
    let data = BimodalGen::new(2024).generate(engine.cluster_mut(), 20_000_000);

    println!(
        "\n{:<8} {:>14} {:>14} {:>12} {:>10}",
        "quantile", "exact (GK Sel)", "approx (GK Sk)", "rank drift", "rounds"
    );
    for q in [0.95, 0.99, 0.999] {
        let exact = engine.execute(Source::Dataset(&data), QuantileQuery::Single(q))?;
        let approx = engine.execute(
            Source::Dataset(&data),
            QuantileQuery::Sketched { q, eps: 0.005 },
        )?;

        // measure how many ranks the approximation drifted
        let mut all = data.to_vec();
        all.sort_unstable();
        let true_rank = gkselect::target_rank(data.len(), q);
        let approx_rank = all.partition_point(|&x| x < approx.value()) as u64;
        let drift = approx_rank.abs_diff(true_rank);

        let truth = oracle_quantile(&data, q).expect("nonempty");
        assert_eq!(exact.value(), truth, "exactness violated at q={q}");

        println!(
            "p{:<7} {:>14} {:>14} {:>12} {:>10}",
            q * 100.0,
            exact.value(),
            approx.value(),
            drift,
            exact.report.rounds
        );
    }

    println!("\nGK Select returned the exact tail quantiles in ≤3 rounds each;");
    println!("the sketch's answers drifted by the rank margins shown above.");
    Ok(())
}
