//! Threads-vs-sequential executor pool comparison on the paper's
//! `emr(30)` shape: same data, same GK Select query, once through the
//! sequential substrate and once through the OS-thread executor pool —
//! two engines differing only in `exec_mode`, one `execute` call each.
//!
//! Prints, per mode: the (identical) exact answer and round/scan
//! counters, the virtual-clock model seconds, the *real* stage
//! wall-clock, the fused band-extract scan's wall-clock, and the pool's
//! utilization / busy-skew ledger.
//!
//! Results and counters are bit-identical across modes. Model seconds
//! are **not** compared: under `Threads` the measured per-partition
//! times include real scheduling and contention (30 threads on however
//! many cores this box has), so the virtual clock absorbs that — the
//! sequential run is the canonical source of modelled figures, the
//! threaded run of real parallel wall-clock.
//!
//! ```bash
//! cargo run --release --example threads_vs_sequential [n]
//! ```

use gkselect::prelude::*;

fn run(mode: ExecMode, n: u64) -> QueryOutcome {
    let mut engine = EngineBuilder::new()
        .cluster(ClusterConfig::emr(30).with_exec_mode(mode))
        .algorithm(AlgoChoice::GkSelect)
        .build()
        .expect("engine build");
    let data = UniformGen::new(42).generate(engine.cluster_mut(), n);
    engine
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.75))
        .expect("gk select run")
}

fn main() {
    let n: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4_000_000);

    println!("GK Select q=0.75, n={n}, emr(30): sequential vs thread-pool executors\n");
    println!(
        "{:<12} {:>12} {:>7} {:>6} {:>10} {:>10} {:>10} {:>6} {:>6}",
        "mode", "value", "rounds", "scans", "model s", "wall s", "band-scan", "util", "skew"
    );
    let mut outs = Vec::new();
    for mode in [ExecMode::Sequential, ExecMode::Threads] {
        let out = run(mode, n);
        println!(
            "{:<12} {:>12} {:>7} {:>6} {:>10.4} {:>10.4} {:>10.4} {:>6.2} {:>6.2}",
            mode.label(),
            out.value(),
            out.report.rounds,
            out.report.data_scans,
            out.report.elapsed_secs,
            out.report.wall_stage_secs,
            out.report.stage_walls.get(1).copied().unwrap_or(0.0),
            out.report.executor_utilization,
            out.report.busy_skew,
        );
        outs.push(out);
    }

    let (seq, thr) = (&outs[0], &outs[1]);
    assert_eq!(seq.value(), thr.value(), "modes must agree on the exact answer");
    assert_eq!(seq.report.rounds, thr.report.rounds);
    assert_eq!(seq.report.data_scans, thr.report.data_scans);
    assert_eq!(
        seq.report.network_volume_bytes, thr.report.network_volume_bytes,
        "byte accounting must be mode-independent"
    );

    // sanity vs the oracle on a fresh (sequential) cluster
    let mut cluster = Cluster::new(ClusterConfig::emr(30));
    let data = UniformGen::new(42).generate(&mut cluster, n);
    let truth = oracle_quantile(&data, 0.75).expect("nonempty");
    assert_eq!(seq.value(), truth, "exactness");

    println!(
        "\nidentical results & counters across modes (oracle ✓); \
         real stage wall: {:.4}s sequential vs {:.4}s threads on this box",
        seq.report.wall_stage_secs, thr.report.wall_stage_secs
    );
}
