//! Telemetry-pipeline scenario: percentile monitoring over skewed access
//! logs (the paper's power-law motivation).
//!
//! A Zipf-distributed key stream (hot endpoints dominate, like request
//! latencies bucketed by route) is ingested into a growing dataset;
//! after each ingest window the pipeline computes the exact p50/p99 and
//! compares what every algorithm charges the cluster for that answer —
//! the Table V trade-offs on a realistic workload. One `QuantileEngine`
//! per strategy; one `execute` call site for all of them.
//!
//! ```bash
//! cargo run --release --example telemetry_pipeline
//! ```

use gkselect::cluster::metrics::human_bytes;
use gkselect::prelude::*;

fn main() -> anyhow::Result<()> {
    let mut gk = EngineBuilder::new()
        .cluster(ClusterConfig::emr(10))
        .algorithm(AlgoChoice::GkSelect)
        .build()?;
    let mut sort = EngineBuilder::new()
        .cluster(ClusterConfig::emr(10))
        .algorithm(AlgoChoice::FullSort)
        .build()?;

    for (window, n) in [(1, 2_000_000u64), (2, 5_000_000), (3, 10_000_000)] {
        println!("── ingest window {window}: {n} zipf-distributed events ──");
        let data = ZipfGen::new(100 + window as u64, 2.5).generate(gk.cluster_mut(), n);

        let truth_p99 = oracle_quantile(&data, 0.99).expect("nonempty");

        // exact path
        let exact = gk.execute(Source::Dataset(&data), QuantileQuery::Single(0.99))?;
        assert_eq!(exact.value(), truth_p99);

        // approx path (same engine, a Sketched plan)
        let approx = gk.execute(
            Source::Dataset(&data),
            QuantileQuery::Sketched { q: 0.99, eps: 0.01 },
        )?;

        // the Spark-default exact path
        let sorted = sort.execute(Source::Dataset(&data), QuantileQuery::Single(0.99))?;
        assert_eq!(sorted.value(), truth_p99);

        println!(
            "{:<12} {:>12} {:>10} {:>8} {:>12} {:>10}",
            "algorithm", "p99", "model s", "rounds", "net volume", "exact"
        );
        for out in [&exact, &approx, &sorted] {
            println!(
                "{:<12} {:>12} {:>10.4} {:>8} {:>12} {:>10}",
                out.report.algorithm,
                out.value(),
                out.report.elapsed_secs,
                out.report.rounds,
                human_bytes(out.report.network_volume_bytes),
                out.report.exact
            );
        }
        let speedup = sorted.report.elapsed_secs / exact.report.elapsed_secs;
        println!("→ GK Select beat Full Sort by {speedup:.1}× this window\n");
    }
    Ok(())
}
