//! Live telemetry through the streaming quantile service: p50/p95/p99
//! served **exactly** after every ingest tick, from cached sketches —
//! ingest and query both through the one `QuantileEngine`.
//!
//! A zipf-distributed event stream (hot endpoints dominate) arrives in
//! micro-batches. Each tick `engine.ingest` seals the batch as a new
//! epoch and folds it into per-partition GK partials (1 round over the
//! new records only); `engine.execute(Source::Stream(..), Multi(..))`
//! then serves all three percentiles from the cached partials plus one
//! fused band-extract scan — rounds=1 / data_scans=1 per query, where
//! batch GK Select would pay 2/2 rebuilding the sketch every time.
//! Epoch compaction keeps the store's sketch footprint flat while the
//! data keeps growing.
//!
//! ```bash
//! cargo run --release --example streaming_quantiles
//! ```

use gkselect::cluster::metrics::human_bytes;
use gkselect::prelude::*;

fn main() -> anyhow::Result<()> {
    let mut engine = EngineBuilder::new()
        .cluster(ClusterConfig::emr(10))
        .compaction(CompactionPolicy {
            compact_threshold: 4,
            max_live_epochs: 2,
        })
        .build()?;
    let qs = vec![0.5, 0.95, 0.99];

    println!(
        "{:<5} {:>10} {:>10} {:>10} {:>10} {:>7} {:>6} {:>7} {:>11}",
        "tick", "p50", "p95", "p99", "records", "epochs", "rnds", "scans", "store"
    );
    for tick in 1..=8u64 {
        // this tick's events: 400k zipf-distributed keys (DataGenerator
        // is in the prelude)
        let mut batch = Vec::new();
        ZipfGen::new(1000 + tick, 2.5).fill_partition(tick as usize, 1, 400_000, &mut batch);

        let ing = engine.ingest("telemetry", MicroBatch::new(batch))?;
        let out = engine.execute(
            Source::Stream("telemetry"),
            QuantileQuery::Multi(qs.clone()),
        )?;

        // the exactness the service sells: every percentile matches the
        // oracle over everything ingested so far
        let all = engine
            .store()
            .stream("telemetry")
            .expect("ingested")
            .live_dataset()?;
        for (&q, &v) in qs.iter().zip(out.values.iter()) {
            assert_eq!(v, oracle_quantile(&all, q).expect("nonempty"), "q={q}");
        }

        println!(
            "{:<5} {:>10} {:>10} {:>10} {:>10} {:>4}{:>3} {:>6} {:>7} {:>11}",
            tick,
            out.values[0],
            out.values[1],
            out.values[2],
            ing.stream_records,
            ing.live_epochs,
            if ing.compacted_epochs > 0 { " ⤵" } else { "" },
            out.report.rounds,
            out.report.data_scans,
            human_bytes(ing.store_bytes),
        );
    }
    println!(
        "\nevery query: rounds=1, data_scans=1 — the sketch pass was paid at ingest;\n\
         batch GK Select would have paid 2 rounds / 2 full scans per tick (16 scans\n\
         of ever-growing data instead of 8 ingest scans of just the new records)."
    );
    Ok(())
}
