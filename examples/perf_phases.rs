//! §Perf phase-level profiler: times GK Select's three phases
//! (sketch / count / secondPass) separately at n = 1e8 on a modelled
//! 10-node cluster — the measurement loop behind EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo run --release --example perf_phases
//! ```
use gkselect::algorithms::approx_quantile::{build_global_sketch, MergeStrategy, SketchVariant};
use gkselect::cluster::{Cluster, ClusterConfig};
use gkselect::data::{DataGenerator, UniformGen};
use gkselect::runtime::{KernelBackend, NativeBackend};
use std::time::Instant;
fn main() {
    let mut c = Cluster::new(ClusterConfig::emr(10));
    let t = Instant::now();
    let data = UniformGen::new(7).generate(&mut c, 100_000_000);
    println!("gen: {:?}", t.elapsed());
    c.reset_run();
    let t = Instant::now();
    let sk = build_global_sketch(&mut c, &data, SketchVariant::Modified, MergeStrategy::Fold, 0.01).unwrap();
    println!("sketch wall {:?} model {:.4}", t.elapsed(), c.elapsed_secs());
    let pivot = sk.query_quantile(0.5).unwrap();
    let m0 = c.elapsed_secs();
    let t = Instant::now();
    let be = NativeBackend::new();
    let pending = c.map_partitions(&data, |p, _| { let x = be.count_pivot(p, pivot); (x.lt, x.eq, x.gt) }).unwrap();
    let _ = c.reduce(pending, |a, b| (a.0+b.0, a.1+b.1, a.2+b.2));
    println!("count wall {:?} model {:.4}", t.elapsed(), c.elapsed_secs() - m0);
    let m1 = c.elapsed_secs();
    let t = Instant::now();
    let slices = c.map_partitions(&data, |p, ctx| gkselect_secondpass_probe(p, pivot, 500_000, ctx.partition as u64)).unwrap();
    let _ = c.tree_reduce(slices, None, |a, b| { let mut a = a; a.extend_from_slice(&b); if a.len() > 500_000 { a.select_nth_unstable(499_999); a.truncate(500_000);} a });
    println!("secondpass wall {:?} model {:.4}", t.elapsed(), c.elapsed_secs() - m1);
}
fn gkselect_secondpass_probe(part: &[i32], pivot: i32, m: usize, _s: u64) -> Vec<i32> {
    let mut side: Vec<i32> = part.iter().copied().filter(|&v| v > pivot).collect();
    if m < side.len() { side.select_nth_unstable(m - 1); side.truncate(m); }
    side
}
